"""Discovery-process curve (paper §4.4): best-so-far geomean per generation,
plus stage-mix statistics (how many experiments compiled / were incorrect /
improved) — the observable the paper argues shows 'self-consistent directed
action'."""
from __future__ import annotations

from repro.core import EvaluationService, KernelScientist, ScriptedLLM


def run(generations: int = 14, seed: int = 1):
    sci = KernelScientist(llm=ScriptedLLM(seed=seed),
                          service=EvaluationService(seed=seed))
    sci.run(generations=generations)
    rows = []
    for gen, best_us in sci.trajectory():
        rows.append((f"trajectory/gen{gen:02d}_best_us", best_us, ""))
    statuses = {}
    for rec in sci.population:
        statuses[rec.status] = statuses.get(rec.status, 0) + 1
    for status, n in sorted(statuses.items()):
        rows.append((f"trajectory/submissions_{status}", float(n), ""))
    improved = sum(
        1 for i in range(1, len(sci.logbook))
        if sci.logbook[i].best_geomean_us < sci.logbook[i - 1].best_geomean_us)
    rows.append(("trajectory/generations_with_improvement", float(improved),
                 f"of {len(sci.logbook)}"))
    return rows, sci
