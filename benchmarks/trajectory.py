"""Discovery-process curve (paper §4.4): best-so-far geomean per generation,
plus stage-mix statistics (how many experiments compiled / were incorrect /
improved) — the observable the paper argues shows 'self-consistent directed
action'.  The campaign's structured event log (core.events) supplies the
resilience annotations for the figure: retry/fallback density and per-stage
latency, i.e. how much of the multi-day wall clock the paper's loop spent
waiting on the flaky shared queue (§3.4)."""
from __future__ import annotations

from repro.core import (EvaluationService, FlakyLLM, FlakyService,
                        KernelScientist, NO_WAIT_POLICY, ScriptedLLM)


def run(generations: int = 14, seed: int = 1, fault_rate: float = 0.0):
    llm = ScriptedLLM(seed=seed)
    service = EvaluationService(seed=seed)
    if fault_rate:
        llm = FlakyLLM(llm, seed=seed, error_rate=fault_rate / 2,
                       malformed_rate=fault_rate / 2)
        service = FlakyService(service, seed=seed, error_rate=fault_rate)
    sci = KernelScientist(llm=llm, backend=service,
                          retry_policy=NO_WAIT_POLICY)
    sci.run(generations=generations)
    rows = []
    for gen, best_us in sci.trajectory():
        if best_us is not None:   # None = no successful kernel yet
            rows.append((f"trajectory/gen{gen:02d}_best_us", best_us, ""))
    statuses = {}
    for rec in sci.population:
        statuses[rec.status] = statuses.get(rec.status, 0) + 1
    for status, n in sorted(statuses.items()):
        rows.append((f"trajectory/submissions_{status}", float(n), ""))
    improved = sum(
        1 for i in range(1, len(sci.logbook))
        if sci.logbook[i].best_geomean_us < sci.logbook[i - 1].best_geomean_us)
    rows.append(("trajectory/generations_with_improvement", float(improved),
                 f"of {len(sci.logbook)}"))

    # resilience annotations from the structured event log
    counts = sci.events.counts()
    rows.append(("trajectory/retries", float(counts.get("retry", 0)), ""))
    rows.append(("trajectory/fallbacks", float(counts.get("fallback", 0)), ""))
    for stage, durs in sorted(sci.events.stage_durations().items()):
        rows.append((f"trajectory/stage_{stage}_mean_s",
                     sum(durs) / len(durs), f"n={len(durs)}"))
    return rows, sci
