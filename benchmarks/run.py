"""Benchmark harness — one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--fast]

Prints ``name,value,derived`` CSV:
  table1/*      — paper Table 1 reproduction (geomean us + ratios)
  trajectory/*  — §4.4 discovery curve (best-so-far per generation)
  scientist/*   — campaign throughput: submissions/hour + cache hit rate
                  for workers ∈ {1, 3} (also writes BENCH_scientist.json)
  micro/*       — kernel microbenchmarks (interpret wall-clock + v5e est.)
  roofline/*    — §Roofline terms per dry-run cell (needs results/dryrun)
"""
from __future__ import annotations

import argparse
import sys


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="fewer generations for the search benchmarks")
    args = ap.parse_args(argv)
    gens = 6 if args.fast else 20

    rows = []
    from benchmarks import (kernel_micro, roofline_bench, scientist_throughput,
                            table1, trajectory)
    t1, _ = table1.run(generations=gens)
    rows += t1
    tr, _ = trajectory.run(generations=max(4, gens // 2))
    rows += tr
    st, _ = scientist_throughput.run(generations=max(4, gens // 3))
    rows += st
    rows += kernel_micro.run()
    rows += roofline_bench.run()

    print("name,value,derived")
    for name, value, derived in rows:
        v = f"{value:.4f}" if isinstance(value, float) else str(value)
        print(f"{name},{v},{derived}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
