"""Roofline table assembly from the dry-run artifacts (results/dryrun)."""
from __future__ import annotations

import pathlib

from repro.roofline import report

RESULT_DIR = pathlib.Path("results/dryrun")


def run():
    rows = []
    if not RESULT_DIR.exists():
        return [("roofline/missing", 0.0,
                 "run: python -m repro.launch.dryrun --all --mesh both "
                 "--out results/dryrun")]
    cells = report.assemble(RESULT_DIR, mesh="single")
    for r in sorted(cells, key=lambda r: (r["arch"], r["shape"])):
        key = f"roofline/{r['arch']}/{r['shape']}"
        rows.append((f"{key}/bound_s", r["step_lower_bound_s"],
                     f"dominant={r['dominant']} "
                     f"useful={r['useful_flops_ratio']:.2f} "
                     f"hbm={r['hbm_gib_per_device']:.1f}GiB"))
    multi_ok = sum(1 for rec in report.load_records(RESULT_DIR)
                   if rec["mesh"] == "multi" and rec["status"] == "ok")
    single_ok = len(cells)
    rows.append(("roofline/cells_single_ok", float(single_ok), "of 31"))
    rows.append(("roofline/cells_multi_ok", float(multi_ok), "of 31"))
    return rows
