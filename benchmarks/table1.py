"""Paper Table 1 reproduction: geometric-mean execution time over the AMD
challenge configurations for (library reference / naive translation /
Kernel-Scientist best), on the TPU-v5e analytic platform.

The paper's absolute numbers are MI300 (fp8 MFMA ~2.6 PFLOP/s); ours are
v5e bf16 (197 TFLOP/s), so the COMPARISON is the ratio columns.  A
brute-force sweep of the genome space provides the attainable floor — the
scientist's distance to it is the search-quality metric.
"""
from __future__ import annotations

import itertools

from repro.core import (
    BENCH_CONFIGS_18, EvaluationService, KernelGenome, KernelScientist,
    ScriptedLLM,
)
from repro.core.evaluator import PlatformCompileError, estimate_us
from repro.core.population import geomean


def brute_force_floor(configs=BENCH_CONFIGS_18):
    best = (float("inf"), None)
    for bm, bn, bk in itertools.product((128, 256, 512, 1024, 2048),
                                        repeat=3):
        for sa in ("scale_acc", "dequant_inputs"):
            g = KernelGenome(style="blocked", block_m=bm, block_n=bn,
                             block_k=bk, scale_application=sa)
            if g.validate():
                continue
            try:
                s = geomean(estimate_us(g, *c) for c in configs)
            except PlatformCompileError:
                continue
            if s < best[0]:
                best = (s, g)
    return best


def run(generations: int = 20, seed: int = 0):
    sci = KernelScientist(llm=ScriptedLLM(seed=seed),
                          service=EvaluationService(seed=seed))
    best = sci.run(generations=generations)
    lib = sci.population.get("00001")
    naive = sci.population.get("00002")
    mxu = sci.population.get("00003")
    floor_us, floor_g = brute_force_floor()

    rows = [
        ("table1/library_reference_us", lib.score,
         "paper: PyTorch reference ~850us on MI300"),
        ("table1/naive_translation_us", naive.score,
         "paper: naive HIP ~5000us"),
        ("table1/mxu_seed_us", mxu.score, "paper: first Matrix-Core kernel"),
        ("table1/scientist_best_us", best.score,
         f"best genome: {best.genome.describe() if best.genome else '?'}"),
        ("table1/bruteforce_floor_us", floor_us, floor_g.describe()),
        ("table1/ratio_naive_vs_library", naive.score / lib.score,
         "paper: ~5.9x"),
        ("table1/ratio_scientist_vs_library", best.score / lib.score,
         "paper: ~0.53x"),
        ("table1/search_quality_floor_frac", floor_us / best.score,
         "1.0 = scientist found the attainable optimum"),
        ("table1/generations", float(generations),
         f"{sci.pool.submissions} platform submissions"),
    ]
    return rows, sci
