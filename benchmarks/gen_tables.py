"""Regenerate the EXPERIMENTS.md dry-run + roofline tables from
results/dryrun/*.json (replaces the <!-- DRYRUN_TABLE --> and
<!-- ROOFLINE_TABLE --> markers)."""
from __future__ import annotations

import json
import pathlib
import re

from repro import configs
from repro.roofline import report

RESULTS = pathlib.Path("results/dryrun")
EXP = pathlib.Path("EXPERIMENTS.md")


def dryrun_table() -> str:
    recs = report.load_records(RESULTS)
    by_cell = {}
    for r in recs:
        by_cell.setdefault((r["arch"], r["shape"]), {})[r["mesh"]] = r
    lines = ["| arch | shape | single (16,16) | multi (2,16,16) | "
             "compile s | HBM GiB/dev |",
             "|---|---|---|---|---|---|"]
    n_ok = {"single": 0, "multi": 0}
    for (arch, shape), ms in sorted(by_cell.items()):
        cells = []
        for mesh in ("single", "multi"):
            r = ms.get(mesh, {})
            st = r.get("status", "?")
            if st == "ok":
                n_ok[mesh] += 1
            cells.append({"ok": "OK", "skipped": "skip",
                          "error": "FAIL"}.get(st, "?"))
        r = ms.get("single", {})
        if r.get("status") == "ok":
            mem = r["prod"]["memory"]
            hbm = (mem.get("argument_size_in_bytes", 0)
                   + mem.get("temp_size_in_bytes", 0)) / 2**30
            extra = [f"{r.get('compile_s', 0):.0f}", f"{hbm:.1f}"]
        elif r.get("status") == "skipped":
            extra = ["—", r.get("reason", "")[:40]]
        else:
            extra = ["—", "—"]
        lines.append(f"| {arch} | {shape} | {cells[0]} | {cells[1]} | "
                     f"{extra[0]} | {extra[1]} |")
    lines.append("")
    lines.append(f"**{n_ok['single']}/31 single-pod OK, "
                 f"{n_ok['multi']}/31 multi-pod OK** "
                 "(9 cells skipped per assignment rules).")
    return "\n".join(lines)


def _splice(text: str, name: str, content: str) -> str:
    """Replace (or create from a bare marker) a START/END-delimited block."""
    start, end = f"<!-- {name}_START -->", f"<!-- {name}_END -->"
    block = f"{start}\n{content}\n{end}"
    if start in text:
        return re.sub(re.escape(start) + r".*?" + re.escape(end), 
                      lambda _: block, text, flags=re.S)
    return text.replace(f"<!-- {name} -->", block)


def main() -> None:
    roof = report.markdown_table(report.assemble(RESULTS, mesh="single"))
    text = EXP.read_text()
    text = _splice(text, "DRYRUN_TABLE", dryrun_table())
    text = _splice(text, "ROOFLINE_TABLE", roof)
    EXP.write_text(text)
    print("EXPERIMENTS.md tables regenerated")


if __name__ == "__main__":
    main()
