"""Campaign-throughput benchmark: what the evaluation pool + eval cache buy.

Runs the same seeded campaign at ``workers ∈ {1, 3}`` against evaluation
services with a modelled shared-queue service delay (paper §3.4: the
campaigns were wall-clock-bound by the external evaluation queue), then
resumes the campaign and re-probes every population member through the
pool's low-priority lane to measure the content-addressed cache.

Records, per worker count: submissions/hour, generation wall-clock, cache
hit rate, and best geomean — into ``BENCH_scientist.json`` (the campaign
perf-trajectory artifact) and as ``scientist/*`` CSV rows.
"""
from __future__ import annotations

import json
import pathlib
import tempfile
import time

from repro.core import (EvalCache, EvalPool, EvaluationService,
                        KernelScientist, NO_WAIT_POLICY, ScriptedLLM)

DEFAULT_OUT = pathlib.Path(__file__).resolve().parent.parent / \
    "BENCH_scientist.json"


def _backend(workdir, seed, noise, latency_s, workers, transport):
    return EvalPool.of(
        EvaluationService(noise=noise, seed=seed, latency_s=latency_s),
        workers=workers, cache=EvalCache(
            pathlib.Path(workdir) / "eval_cache.jsonl"),
        retry_policy=NO_WAIT_POLICY, transport=transport)


def _campaign(workdir, seed, noise, latency_s, workers,
              transport="inprocess"):
    return KernelScientist(
        llm=ScriptedLLM(seed=seed),
        backend=_backend(workdir, seed, noise, latency_s, workers,
                         transport),
        workdir=workdir, retry_policy=NO_WAIT_POLICY)


def run(generations: int = 6, seed: int = 3, noise: float = 0.05,
        latency_s: float = 0.9, out_path=DEFAULT_OUT,
        transport: str = "inprocess"):
    rows, bench = [], {"generations": generations, "seed": seed,
                       "noise": noise, "latency_s": latency_s,
                       "transport": transport, "workers": {}}
    for workers in (1, 3):
        with tempfile.TemporaryDirectory() as wd:
            t0 = time.perf_counter()
            sci = _campaign(wd, seed, noise, latency_s, workers, transport)
            best = sci.run(generations)
            wall_s = time.perf_counter() - t0
            stats = sci.pool.stats()
            subs_per_hour = stats["submissions"] / wall_s * 3600.0
            gen_wall_s = wall_s / generations

            # resumed campaign: re-probe every kernel through the pool's
            # idle-priority lane — the content-addressed cache answers for
            # everything the platform has already timed
            resumed = KernelScientist.resume(
                wd, llm=ScriptedLLM(seed=seed),
                backend=_backend(wd, seed, noise, latency_s, workers,
                                 transport),
                retry_policy=NO_WAIT_POLICY)
            handles = [resumed.pool.probe(r.source, tag=r.rid)
                       for r in resumed.population]
            for h in handles:
                h.result()
            cache = resumed.pool.cache
            lookups = cache.hits + cache.misses
            hit_rate = cache.hits / lookups if lookups else 0.0
            resumed.pool.close()
            sci.pool.close()

            entry = {
                "wall_s": round(wall_s, 3),
                "generation_wall_s": round(gen_wall_s, 3),
                "submissions": stats["submissions"],
                "submissions_per_hour": round(subs_per_hour, 1),
                "cache_hits_campaign": stats.get("cache_hits", 0),
                "cache_misses_campaign": stats.get("cache_misses", 0),
                "resumed_probe_hit_rate": round(hit_rate, 4),
                "best_geomean_us": round(best.score, 3),
            }
            bench["workers"][str(workers)] = entry
            w = f"scientist/workers{workers}"
            rows.append((f"{w}_submissions_per_hour", subs_per_hour, ""))
            rows.append((f"{w}_generation_wall_s", gen_wall_s, ""))
            rows.append((f"{w}_best_geomean_us", best.score, ""))
            rows.append((f"{w}_resumed_cache_hit_rate", hit_rate,
                         f"{cache.hits} hits / {lookups} lookups"))

    w1 = bench["workers"]["1"]["submissions_per_hour"]
    w3 = bench["workers"]["3"]["submissions_per_hour"]
    bench["speedup_workers3_vs_1"] = round(w3 / w1, 3) if w1 else None
    same_best = (bench["workers"]["1"]["best_geomean_us"]
                 == bench["workers"]["3"]["best_geomean_us"])
    bench["trajectory_identical"] = same_best
    rows.append(("scientist/speedup_workers3_vs_1",
                 w3 / w1 if w1 else 0.0,
                 "submissions/hour, pool vs sequential"))
    rows.append(("scientist/trajectory_identical", float(same_best),
                 "workers=3 best geomean == workers=1"))

    if out_path:
        out_path = pathlib.Path(out_path)
        out_path.write_text(json.dumps(bench, indent=1) + "\n")
    return rows, bench


if __name__ == "__main__":
    for name, value, derived in run()[0]:
        print(f"{name},{value:.4f},{derived}")
