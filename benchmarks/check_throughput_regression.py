"""CI guard: fail when campaign throughput regresses past a threshold.

Re-runs the ``scientist_throughput`` benchmark fresh and compares
``workers=3`` ``submissions_per_hour`` against the committed
``BENCH_scientist.json`` baseline.  The intended catch is integrity-layer
overhead creep: the verdict-trust machinery (``core.integrity``) is
default-off, so the audited code path must cost ~nothing when disabled — a
>15% throughput drop means something started paying per-submission work it
shouldn't.

The comparison is robust to machine speed because the benchmark's modelled
queue latency (``latency_s=0.9`` per submission) dominates wall-clock: the
metric mostly measures scheduling overlap, not CPU.

    PYTHONPATH=src python benchmarks/check_throughput_regression.py

Exits 0 when within threshold, 1 on regression (with both numbers printed).
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys

from scientist_throughput import run as run_bench  # noqa: E402

BASELINE = pathlib.Path(__file__).resolve().parent.parent / \
    "BENCH_scientist.json"
METRIC = "submissions_per_hour"
WORKERS = "3"


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--baseline", default=str(BASELINE),
                    help="committed BENCH_scientist.json to compare against")
    ap.add_argument("--threshold", type=float, default=0.15,
                    help="maximum tolerated fractional drop (default 0.15)")
    args = ap.parse_args(argv)

    baseline = json.loads(pathlib.Path(args.baseline).read_text())
    expected = baseline["workers"][WORKERS][METRIC]

    # fresh run; out_path=None leaves the committed baseline untouched
    _, bench = run_bench(out_path=None)
    measured = bench["workers"][WORKERS][METRIC]

    drop = (expected - measured) / expected if expected else 0.0
    verdict = "REGRESSION" if drop > args.threshold else "ok"
    print(f"workers={WORKERS} {METRIC}: baseline {expected:.1f}, "
          f"measured {measured:.1f} "
          f"({-drop:+.1%} vs baseline, threshold -{args.threshold:.0%}) "
          f"-> {verdict}")
    return 1 if verdict == "REGRESSION" else 0


if __name__ == "__main__":
    sys.exit(main())
