"""Kernel microbenchmarks: wall-clock (CPU interpret, relative signal only)
for the Pallas kernels at small shapes, plus TPU-v5e analytic estimates at
the challenge shapes for the scientist's key genome variants."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import KernelGenome
from repro.core.evaluator import estimate_us
from repro.kernels import ops, ref


def _time(fn, *args, reps=3):
    jax.block_until_ready(fn(*args))
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        best = min(best, time.perf_counter() - t0)
    return best * 1e6


def run():
    rows = []
    rng = np.random.default_rng(0)

    # interpret-mode wall clock (small problem; relative only)
    m = k = n = 256
    a32 = jnp.asarray(rng.standard_normal((m, k)), jnp.float32)
    b32 = jnp.asarray(rng.standard_normal((k, n)), jnp.float32)
    aq, a_s = ref.quantize_blockwise(a32)
    bq, b_s = ref.quantize_blockwise_2d(b32)
    rows.append(("micro/scaled_gemm_interp_us",
                 _time(lambda *x: ops.scaled_gemm(*x, block_m=128,
                                                  block_n=128, block_k=128),
                       aq, bq, a_s, b_s),
                 "256^3 CPU interpret (relative signal)"))
    rows.append(("micro/scaled_gemm_ref_us",
                 _time(jax.jit(ref.scaled_gemm), aq, bq, a_s, b_s),
                 "jnp oracle, jitted"))

    q = jnp.asarray(rng.standard_normal((1, 4, 256, 64)), jnp.float32)
    kk = jnp.asarray(rng.standard_normal((1, 2, 256, 64)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((1, 2, 256, 64)), jnp.float32)
    rows.append(("micro/flash_attention_interp_us",
                 _time(lambda *x: ops.attention(*x, block_q=128,
                                                block_k=128), q, kk, v),
                 "B1 H4 S256 D64"))

    # v5e analytic: genome ablation at a representative challenge shape
    shape = (6144, 7168, 2048)
    for name, g in (
        ("blocked_128", KernelGenome(block_m=128, block_n=128, block_k=128)),
        ("blocked_512", KernelGenome(block_m=512, block_n=512, block_k=512)),
        ("best_2048x256x512", KernelGenome(block_m=2048, block_n=256,
                                           block_k=512)),
        ("f32_path", KernelGenome(block_m=512, block_n=512, block_k=512,
                                  compute_dtype="float32")),
        ("dequant_inputs", KernelGenome(block_m=512, block_n=512,
                                        block_k=512,
                                        scale_application="dequant_inputs")),
        ("split_k4", KernelGenome(block_m=512, block_n=512, block_k=512,
                                  k_split=4)),
    ):
        rows.append((f"micro/v5e_est_{name}_us", estimate_us(g, *shape),
                     f"m{shape[0]} n{shape[1]} k{shape[2]}"))
    return rows
