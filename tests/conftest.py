"""Shared fixtures.  NOTE: no XLA_FLAGS here — tests see the real single
CPU device; multi-device tests spawn subprocesses with their own flags."""
import numpy as np
import pytest

try:                                   # gated dependency: use the real
    import hypothesis                  # noqa: F401  package when present,
except ImportError:                    # else the deterministic shim
    import _hypothesis_shim
    _hypothesis_shim.install()


@pytest.fixture
def rng():
    return np.random.default_rng(0)


@pytest.fixture(autouse=True)
def _reset_partition_mesh():
    """The dist mesh registry is process-global; never leak one test's mesh
    into the next (a stale mesh turns shard_named into a hard error on the
    single real device)."""
    yield
    from repro.dist import partition
    partition.set_mesh(None)
