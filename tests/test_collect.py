"""Collection smoke: the whole suite must *import* cleanly.

A single broken import (a missing optional dependency, a renamed jax
symbol) silently knocks out every test in that module under plain
``pytest``; this test turns that into one loud failure.  Runs pytest in a
subprocess so a collection error cannot take this guard down with it.
"""
import os
import pathlib
import subprocess
import sys

REPO = pathlib.Path(__file__).resolve().parents[1]


def test_collect_only_reports_zero_errors():
    env = dict(os.environ)
    env["PYTHONPATH"] = str(REPO / "src") + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    proc = subprocess.run(
        [sys.executable, "-m", "pytest", "--collect-only", "-q"],
        cwd=REPO, env=env, capture_output=True, text=True, timeout=300)
    out = proc.stdout + proc.stderr
    assert proc.returncode == 0, f"collection failed:\n{out[-4000:]}"
    assert "error" not in out.splitlines()[-1].lower(), out[-2000:]
