"""Fast-lane unit tests for repro.dist: spec inference and the mesh
registry.  No subprocesses, no multi-device requirement — spec functions
only read ``mesh.shape``, so a duck-typed stand-in exercises every
divisibility branch on the single real CPU device."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.dist import compression, partition


class FakeMesh:
    """Spec inference touches only ``.shape`` (a name->size mapping)."""

    def __init__(self, **axes):
        self.shape = dict(axes)


MESH_2D = FakeMesh(data=2, model=4)
MESH_3D = FakeMesh(pod=2, data=2, model=2)
MESH_1D = FakeMesh(data=1, model=1)


# ---------------------------------------------------------------------------
# Mesh registry + shard_named
# ---------------------------------------------------------------------------
def test_no_mesh_is_identity():
    partition.set_mesh(None)
    x = jnp.ones((4, 8))
    assert partition.shard_named(x, ("D", "T")) is x
    assert partition.shard_activation(x) is x


def test_registry_roundtrip():
    partition.set_mesh(MESH_2D)
    assert partition.get_mesh() is MESH_2D
    partition.set_mesh(None)
    assert partition.get_mesh() is None


def test_unknown_tag_raises():
    mesh = jax.make_mesh((1,), ("data",))
    partition.set_mesh(mesh)
    with pytest.raises(ValueError, match="unknown shard tag"):
        partition.shard_named(jnp.ones((4,)), ("X",))


def test_tag_arity_must_match_rank():
    mesh = jax.make_mesh((1,), ("data",))
    partition.set_mesh(mesh)
    with pytest.raises(AssertionError):
        partition.shard_named(jnp.ones((4, 4)), ("D",))


def test_shard_named_on_real_single_device_mesh():
    """On a trivial concrete mesh every tag resolves to replicated and the
    constraint is still applied (values unchanged)."""
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    partition.set_mesh(mesh)
    x = jnp.arange(32.0).reshape(4, 8)
    y = partition.shard_named(x, ("D", "T"))
    np.testing.assert_array_equal(np.asarray(y), np.asarray(x))


# ---------------------------------------------------------------------------
# Axis resolution
# ---------------------------------------------------------------------------
def test_data_axes_folds_pod_and_data():
    assert partition._data_axes(MESH_3D, 8) == ("pod", "data")
    # batch=2 cannot take pod*data=4 -> single data axis
    assert partition._data_axes(MESH_3D, 2) == ("data",)
    # indivisible stays replicated
    assert partition._data_axes(MESH_3D, 3) is None
    # degenerate mesh never shards
    assert partition._data_axes(MESH_1D, 64) is None


# ---------------------------------------------------------------------------
# param_specs
# ---------------------------------------------------------------------------
def test_small_leaves_replicate():
    params = {"layers": {"norm": jnp.ones((4, 64))},
              "bias": jnp.ones((256,))}
    specs = partition.param_specs(params, MESH_2D)
    assert specs == {"layers": {"norm": P()}, "bias": P()}


def test_stacked_layer_dim_never_sharded():
    params = {"layers": {"wq": jnp.ones((4, 256, 512))}}
    specs = partition.param_specs(params, MESH_2D)
    # column-parallel: last dim over model, layer dim untouched
    assert specs["layers"]["wq"] == P(None, None, "model")


def test_row_parallel_shards_input_dim():
    params = {"layers": {"wo": jnp.ones((4, 256, 512))}}
    specs = partition.param_specs(params, MESH_2D)
    assert specs["layers"]["wo"] == P(None, "model", None)


def test_indivisible_tp_dim_falls_back_to_other_dim():
    # last dim 255 % model=4 != 0, but 256 divides -> shard the other dim
    params = {"w_up": jnp.ones((256, 255))}
    specs = partition.param_specs(params, MESH_2D)
    assert specs["w_up"] == P("model", None)


def test_fully_indivisible_replicates():
    params = {"w_up": jnp.ones((255, 129))}
    assert partition.param_specs(params, MESH_2D)["w_up"] == P()


def test_fsdp_only_for_large_train_leaves():
    big = jnp.ones((2048, 2048))       # 4M elems >= FSDP_MIN_ELEMS
    small = jnp.ones((128, 512))       # 64K elems: TP only
    specs = partition.param_specs({"wq": big, "wk": small}, MESH_2D)
    assert specs["wq"] == P("data", "model")
    assert specs["wk"] == P(None, "model")
    serve = partition.param_specs({"wq": big}, MESH_2D, mode="serve")
    # serve folds (data, model) onto the TP dim instead of FSDP
    assert serve["wq"] == P(None, ("data", "model"))


def test_moe_expert_stack_expert_parallel():
    params = {"layers": {"moe": {"w_up": jnp.ones((2, 8, 64, 128))}}}
    specs = partition.param_specs(params, MESH_2D)
    # (L, E, d, f): E over model, body too small for FSDP
    assert specs["layers"]["moe"]["w_up"] == P(None, "model", None, None)


def test_pod_axis_never_shards_params():
    params = {"wq": jnp.ones((2048, 2048))}
    specs = partition.param_specs(params, MESH_3D)
    for entry in specs["wq"]:
        assert entry != "pod" and entry != ("pod",)


# ---------------------------------------------------------------------------
# batch_specs / cache_specs
# ---------------------------------------------------------------------------
def test_batch_specs_batch_major():
    batch = {"tokens": jnp.ones((8, 64), jnp.int32),
             "positions": jnp.ones((3, 8, 64), jnp.int32),
             "scalar": jnp.float32(1.0)}
    specs = partition.batch_specs(batch, MESH_2D)
    assert specs["tokens"] == P("data", None)
    assert specs["positions"] == P(None, "data", None)
    assert specs["scalar"] == P()


def test_cache_specs_kv_heads_over_model():
    cache = {"k": jnp.ones((2, 8, 64, 4, 32)),
             "len": jnp.ones((8,), jnp.int32)}
    specs = partition.cache_specs(cache, MESH_2D)
    assert specs["k"] == P(None, "data", None, "model", None)
    assert specs["len"] == P("data")


def test_cache_specs_indivisible_heads_replicate():
    cache = {"k": jnp.ones((2, 8, 64, 3, 32))}   # 3 heads % model=4
    specs = partition.cache_specs(cache, MESH_2D)
    assert specs["k"] == P(None, "data", None, None, None)


# ---------------------------------------------------------------------------
# compression (single-pod path: identical numerics, no collective)
# ---------------------------------------------------------------------------
def test_error_feedback_single_pod():
    g = {"w": jnp.asarray(np.random.default_rng(0).normal(size=(16, 64)),
                          jnp.float32)}
    err = compression.init_error_state(g)
    mean, new_err = compression.cross_pod_mean(g, err, MESH_1D)
    scale = float(jnp.max(jnp.abs(g["w"]))) / 127.0
    # one-step quantisation error bounded by half a step
    assert float(jnp.max(jnp.abs(mean["w"] - g["w"]))) <= scale / 2 + 1e-7
    # residual carries exactly what the mean dropped
    np.testing.assert_allclose(
        np.asarray(new_err["w"]), np.asarray(g["w"] - mean["w"]), atol=1e-6)


def test_wire_bytes_ratio():
    g = {"w": jnp.ones((256, 256), jnp.float32)}
    stats = compression.wire_bytes(g)
    assert stats["raw"] == 256 * 256 * 4
    assert stats["compressed"] == 256 * 256 + 4
    assert stats["ratio"] > 3.9
