"""Train-step semantics: microbatch accumulation parity, donation safety,
deterministic resume math."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.models import api
from repro.optim import adamw
from repro.train import make_prefill_step, make_train_step


def _setup(mb_vocab_seed=0):
    cfg = dataclasses.replace(configs.get_reduced("qwen2.5-3b"),
                              param_dtype="float32")
    params = api.init_params(cfg, jax.random.key(mb_vocab_seed))
    opt = adamw.init(params)
    batch = api.make_batch(cfg, 4, 64)
    return cfg, params, opt, batch


def test_microbatched_step_matches_full_batch():
    cfg, params, opt, batch = _setup()
    s1 = jax.jit(make_train_step(cfg, peak_lr=1e-3, total_steps=10))
    s2 = jax.jit(make_train_step(cfg, peak_lr=1e-3, total_steps=10,
                                 microbatches=2))
    p1, o1, m1 = s1(params, opt, batch, jnp.int32(0))
    p2, o2, m2 = s2(params, opt, batch, jnp.int32(0))
    # microbatch losses are means over slices; grads averaged — parity up
    # to f32 reduction order
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]),
                               rtol=1e-5)
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=2e-5, rtol=1e-4)


def test_microbatched_prefill_matches_full():
    cfg, params, _, batch = _setup()
    f1 = jax.jit(make_prefill_step(cfg, 96))
    f2 = jax.jit(make_prefill_step(cfg, 96, microbatches=2))
    l1, c1 = f1(params, batch)
    l2, c2 = f2(params, batch)
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2),
                               atol=1e-4, rtol=1e-4)
    for k in c1:
        np.testing.assert_allclose(np.asarray(c1[k]), np.asarray(c2[k]),
                                   atol=1e-4, rtol=1e-4,
                                   err_msg=k)


def test_two_steps_deterministic():
    cfg, params, opt, batch = _setup()
    step = jax.jit(make_train_step(cfg, peak_lr=1e-3, total_steps=10))
    pa, oa, _ = step(params, opt, batch, jnp.int32(0))
    cfg2, params2, opt2, batch2 = _setup()
    pb, ob, _ = step(params2, opt2, batch2, jnp.int32(0))
    for a, b in zip(jax.tree.leaves(pa), jax.tree.leaves(pb)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
