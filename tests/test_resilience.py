"""Campaign resilience: retry/backoff, seeded fault injection, per-submission
persistence, kill-and-resume equivalence, and the structured event log.

The two acceptance scenarios of the resilience layer:
  * kill-and-resume — a campaign interrupted (at a generation boundary or
    mid-generation) and resumed from its workdir produces a trajectory
    bitwise-identical to an uninterrupted same-seed run;
  * fault-injection soak — a 10-generation campaign completes with zero
    aborted generations under >= 20% injected transient-failure rate.
"""
import dataclasses
import json

import pytest

from repro.core import designer, resilience, selector, writer
from repro.core.evaluator import EvaluationService
from repro.core.events import EventLog
from repro.core.integrity import Integrity
from repro.core.llm import ScriptedLLM
from repro.core.population import KernelRecord, Population
from repro.core.resilience import (
    NO_WAIT_POLICY, FlakyLLM, FlakyService, RetryPolicy, TransientError,
    retry_call,
)
from repro.core.scientist import GenerationLog, KernelScientist


# ---------------------------------------------------------------------------
# retry_call / RetryPolicy
# ---------------------------------------------------------------------------
def test_retry_succeeds_after_transient_failures():
    calls = []

    def fn():
        calls.append(1)
        if len(calls) < 3:
            raise TransientError("flaky")
        return "ok"

    slept = []
    out = retry_call(fn, policy=RetryPolicy(base_delay_s=0.01, jitter=0.0),
                     sleep=slept.append)
    assert out == "ok" and len(calls) == 3
    assert slept == [0.01, 0.02]           # exponential backoff


def test_retry_gives_up_after_max_attempts():
    calls = []

    def fn():
        calls.append(1)
        raise TransientError("always down")

    with pytest.raises(TransientError):
        retry_call(fn, policy=RetryPolicy(max_attempts=3, base_delay_s=0.0),
                   sleep=lambda s: None)
    assert len(calls) == 3


def test_retry_does_not_catch_nonretryable():
    calls = []

    def fn():
        calls.append(1)
        raise ZeroDivisionError("bug, not flake")

    with pytest.raises(ZeroDivisionError):
        retry_call(fn, policy=NO_WAIT_POLICY, sleep=lambda s: None)
    assert len(calls) == 1                 # no retry on a real bug


def test_backoff_is_deterministic_and_capped():
    p = RetryPolicy(base_delay_s=1.0, multiplier=3.0, max_delay_s=5.0,
                    jitter=0.25, seed=9)
    delays = [p.delay(a) for a in range(1, 6)]
    assert delays == [p.delay(a) for a in range(1, 6)]
    assert all(d <= 5.0 * 1.25 for d in delays)
    assert all(d >= 0.0 for d in delays)


# ---------------------------------------------------------------------------
# Fault injectors
# ---------------------------------------------------------------------------
class _EchoLLM:
    def __init__(self):
        self.calls = 0

    def complete(self, prompt):
        self.calls += 1
        return "{}"


def test_flaky_llm_is_seeded_and_spares_inner_state():
    def pattern_of(flaky):
        out = []
        for _ in range(20):
            try:
                flaky.complete("anything")
                out.append("pass")
            except (TransientError, TimeoutError):
                out.append("fault")
        return out

    inner = _EchoLLM()
    pattern = pattern_of(FlakyLLM(inner, seed=3, error_rate=0.3,
                                  timeout_rate=0.2))
    assert "fault" in pattern and "pass" in pattern
    assert pattern == pattern_of(
        FlakyLLM(_EchoLLM(), seed=3, error_rate=0.3, timeout_rate=0.2))
    # injected faults never consumed the wrapped model's call budget
    assert inner.calls == pattern.count("pass")


def test_flaky_service_delegates_and_injects():
    inner = EvaluationService()
    flaky = FlakyService(inner, seed=1, error_rate=1.0)
    with pytest.raises(TransientError):
        flaky.submit("x = 1")
    assert inner.submissions == 0          # the request "never arrived"
    assert flaky.bench_configs == inner.bench_configs  # drop-in delegation


def test_malformed_reply_is_a_retryable_stage_error():
    flaky = FlakyLLM(ScriptedLLM(), seed=0, error_rate=0.0,
                     malformed_rate=1.0)
    from repro.core import prompts
    with pytest.raises(ValueError):
        prompts.extract_reply_json(flaky.complete("anything"))


# ---------------------------------------------------------------------------
# Satellite regressions (real exceptions, not asserts: these must still
# raise under `python -O`, which strips assert statements)
# ---------------------------------------------------------------------------
def _rec(rid, parents=()):
    return KernelRecord(rid=rid, parents=tuple(parents), source="",
                        genome=None, experiment={})


def test_population_add_invariants_raise_under_O():
    pop = Population()
    pop.add(_rec(pop.new_id()))
    with pytest.raises(ValueError, match="duplicate"):
        pop.add(_rec("00001"))
    with pytest.raises(ValueError, match="unknown parent"):
        pop.add(_rec(pop.new_id(), parents=("99999",)))


def test_designer_validation_raises_under_O():
    with pytest.raises(ValueError, match="no experiment plans"):
        designer.validate_plans([])
    with pytest.raises(ValueError, match="inverted"):
        designer.validate_plans([{"description": "d", "rubric": "r",
                                  "performance": [10, 5], "innovation": 1}])
    with pytest.raises(ValueError, match="innovation"):
        designer.validate_plans([{"description": "d", "rubric": "r",
                                  "performance": [0, 5], "innovation": 400}])
    with pytest.raises(ValueError, match="missing"):
        designer.validate_plans([{"description": "d"}])


def test_seed_goes_through_population_add():
    sci = KernelScientist(llm=ScriptedLLM(), service=EvaluationService())
    sci.seed()
    # seeds now respect Population.add invariants: re-adding any seed rid is
    # rejected, and the id counter is consistent with the stored records
    with pytest.raises(ValueError, match="duplicate"):
        sci.population.add(_rec("00001"))
    assert sci.population.new_id() == "00004"
    with pytest.raises(RuntimeError, match="already seeded"):
        sci.seed()


def test_runtime_error_status_distinct_from_compile_error():
    svc = EvaluationService()
    crashy = ('GENOME = None\n'
              'def run(a, b, a_scale, b_scale, interpret=True):\n'
              '    raise RuntimeError("tile index out of bounds")\n')
    res = svc.submit(crashy)
    assert res.status == "runtime_error"
    assert "tile index out of bounds" in res.error
    # compile failures are still compile_error
    assert svc.submit("this is not python !!").status == "compile_error"


def test_submit_failure_marks_record_failed_not_pending(tmp_path):
    class BrokenService:
        submissions = 0

        def submit(self, source):
            raise TransientError("queue on fire")

    sci = KernelScientist(llm=ScriptedLLM(), service=BrokenService(),
                          workdir=tmp_path, retry_policy=NO_WAIT_POLICY)
    sci.seed()
    assert [r.status for r in sci.population] == ["failed"] * 3
    assert all("queue on fire" in r.error for r in sci.population)
    # the failure is persisted: a resumed campaign sees no ghost "pending"
    reloaded = Population.load(tmp_path / "population.json")
    assert [r.status for r in reloaded] == ["failed"] * 3


def test_no_infinity_token_in_serialized_output(tmp_path):
    log = GenerationLog(generation=1, selection={}, plans=[], picked=[],
                        submitted=[], best_rid="",
                        best_geomean_us=float("inf"))
    text = json.dumps(log.to_dict())
    assert "Infinity" not in text
    assert GenerationLog.from_dict(
        json.loads(text)).best_geomean_us == float("inf")

    class BrokenService:
        submissions = 0

        def submit(self, source):
            raise TransientError("down")

    sci = KernelScientist(llm=ScriptedLLM(), service=BrokenService(),
                          workdir=tmp_path, retry_policy=NO_WAIT_POLICY)
    sci.seed()
    assert sci.trajectory() == [(0, None)]      # not Infinity
    assert "Infinity" not in json.dumps(sci.trajectory())


def test_best_none_does_not_crash_generation(tmp_path):
    """Every submission of a generation failing must yield a logbook entry
    (best_rid empty), not an AttributeError."""
    sci = KernelScientist(llm=ScriptedLLM(), service=EvaluationService(),
                          workdir=tmp_path, retry_policy=NO_WAIT_POLICY)
    sci.seed()

    class BrokenService:
        submissions = 0

        def submit(self, source):
            raise TransientError("queue died after seeding")

    # seeds are ok, so selection works; all 3 submissions then fail
    sci.service = BrokenService()
    log = sci.run_generation(1)
    assert [s[1] for s in log.submitted] == ["failed"] * 3
    assert log.best_rid != ""                   # seeds still hold the best
    text = (tmp_path / "logbook.json").read_text()
    assert "Infinity" not in text


# ---------------------------------------------------------------------------
# Kill-and-resume equivalence
# ---------------------------------------------------------------------------
def _fresh(seed=5, **kw):
    return dict(llm=ScriptedLLM(seed=seed),
                service=EvaluationService(seed=seed, noise=0.02),
                retry_policy=NO_WAIT_POLICY, **kw)


def _snapshot(sci):
    return {
        "trajectory": sci.trajectory(),
        "logbook": [l.to_dict() for l in sci.logbook],
        "population": [(r.rid, r.parents, r.status, r.timings_us)
                       for r in sci.population],
    }


def test_kill_and_resume_at_generation_boundary(tmp_path):
    ref = KernelScientist(**_fresh())
    ref.run(6)

    sci = KernelScientist(**_fresh(), workdir=tmp_path / "wd")
    sci.run(3)
    del sci                                   # "kill" the process

    resumed = KernelScientist.resume(tmp_path / "wd", **_fresh())
    resumed.run(3)
    assert _snapshot(resumed) == _snapshot(ref)


class _CrashingService:
    """Raises KeyboardInterrupt (uncatchable by the retry layer, like a real
    SIGINT/OOM kill) on the n-th submission."""

    def __init__(self, inner, crash_at):
        self.inner = inner
        self.crash_at = crash_at
        self.calls = 0

    def submit(self, source):
        self.calls += 1
        if self.calls == self.crash_at:
            raise KeyboardInterrupt
        return self.inner.submit(source)

    def __getattr__(self, name):              # incl. state_dict passthrough
        return getattr(self.inner, name)


def test_kill_and_resume_mid_generation(tmp_path):
    ref = KernelScientist(**_fresh())
    ref.run(4)

    kw = _fresh()
    # 3 seeds + 3x gen1 + 3x gen2 + 2 of gen3 accepted; crash on the 12th
    # submission — mid-generation-3, one kernel in flight
    kw["service"] = _CrashingService(kw["service"], crash_at=12)
    sci = KernelScientist(**kw, workdir=tmp_path / "wd")
    with pytest.raises(KeyboardInterrupt):
        sci.run(4)
    assert len(sci.logbook) == 2              # gens 1-2 durable, gen 3 cut

    resumed = KernelScientist.resume(tmp_path / "wd", **_fresh())
    assert resumed._inflight is not None
    assert len(resumed._inflight["submitted"]) == 2
    resumed.run(2)                            # finish gen 3, then gen 4
    assert _snapshot(resumed) == _snapshot(ref)


def test_resume_restarts_cleanly_when_killed_mid_seed(tmp_path):
    kw = _fresh()
    kw["service"] = _CrashingService(kw["service"], crash_at=2)
    sci = KernelScientist(**kw, workdir=tmp_path / "wd")
    with pytest.raises(KeyboardInterrupt):
        sci.run(2)

    ref = KernelScientist(**_fresh())
    ref.run(2)
    resumed = KernelScientist.resume(tmp_path / "wd", **_fresh())
    resumed.run(2)
    assert _snapshot(resumed) == _snapshot(ref)


def test_resume_requires_a_campaign(tmp_path):
    with pytest.raises(FileNotFoundError, match="state.json"):
        KernelScientist.resume(tmp_path / "nothing-here")


# ---------------------------------------------------------------------------
# Fault-injection soak
# ---------------------------------------------------------------------------
def test_soak_20pct_faults_completes_10_generations():
    # >= 20% transient faults AND >= 10% silently corrupted timings: the
    # retry layer absorbs the former, the integrity auditor (quorum
    # re-measurement) the latter — the campaign must not abort a single
    # generation under either failure class
    llm = FlakyLLM(ScriptedLLM(seed=11), seed=13,
                   error_rate=0.10, timeout_rate=0.04, malformed_rate=0.06)
    corrupt = resilience.CorruptTimingService(
        EvaluationService(seed=11), seed=29, corrupt_rate=0.10)
    service = FlakyService(corrupt, seed=17, error_rate=0.20)
    integrity = Integrity(quorum_k=3)
    sci = KernelScientist(llm=llm, service=service,
                          retry_policy=NO_WAIT_POLICY, integrity=integrity)
    best = sci.run(10)

    assert len(sci.logbook) == 10             # zero aborted generations
    assert all(len(log.submitted) == 3 for log in sci.logbook)
    assert len(sci.population) == 3 + 30
    assert best is not None and best.score < float("inf")
    # the campaign really was under fire, and the log shows the recovery work
    assert llm.faults > 0 and service.faults > 0
    assert corrupt.corruptions > 0            # corrupted verdicts did occur
    assert integrity.auditor.quorums > 0      # and audits did re-measure
    counts = sci.events.counts()
    assert counts.get("retry", 0) > 0
    traj = [v for _, v in sci.trajectory() if v is not None]
    assert traj == sorted(traj, reverse=True)  # still monotone best-so-far


# ---------------------------------------------------------------------------
# Structured event log
# ---------------------------------------------------------------------------
def test_event_log_jsonl_roundtrip_and_ordering(tmp_path):
    sci = KernelScientist(llm=ScriptedLLM(), service=EvaluationService(),
                          workdir=tmp_path, retry_policy=NO_WAIT_POLICY)
    sci.run(2)
    events = EventLog.read(tmp_path / "events.jsonl")
    assert [e["seq"] for e in events] == list(range(1, len(events) + 1))
    names = [e["event"] for e in events]
    assert names[0] == "campaign_start"
    assert names.count("generation_start") == 2
    assert names.count("generation_end") == 2
    assert names.count("eval_result") == 3 + 6   # seeds + 2 gens x 3
    for e in events:
        if e["event"] == "stage_end":
            assert e["stage"] in ("selector", "designer", "writer")
            assert e["duration_s"] >= 0.0
    durs = sci.events.stage_durations()
    assert set(durs) == {"selector", "designer", "writer"}
    assert len(durs["writer"]) == 6


def test_event_log_continues_sequence_across_resume(tmp_path):
    sci = KernelScientist(**_fresh(), workdir=tmp_path / "wd")
    sci.run(1)
    n = len(EventLog.read(tmp_path / "wd" / "events.jsonl"))
    resumed = KernelScientist.resume(tmp_path / "wd", **_fresh())
    resumed.run(1)
    events = EventLog.read(tmp_path / "wd" / "events.jsonl")
    assert len(events) > n
    assert [e["seq"] for e in events] == list(range(1, len(events) + 1))


def test_fallbacks_keep_generation_alive_when_llm_is_down():
    class DeadLLM:
        def complete(self, prompt):
            raise TransientError("LLM API permanently 503")

    sci = KernelScientist(llm=DeadLLM(), service=EvaluationService(),
                          retry_policy=NO_WAIT_POLICY)
    sci.run(2)
    assert len(sci.logbook) == 2
    assert all(len(log.submitted) == 3 for log in sci.logbook)
    # every stage fell back to its deterministic rule-based decision
    counts = sci.events.counts()
    assert counts["fallback"] == 2 * (1 + 1 + 3)   # selector+designer+3 writers
    assert "(rule-based fallback" in sci.logbook[0].selection["rationale"]
