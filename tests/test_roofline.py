"""Roofline assembly: collective parsing on synthetic HLO + correction math
on synthetic dry-run records + model-flops accounting."""
import pytest

from repro.roofline.collectives import (
    collective_bytes_from_hlo, collective_op_counts,
)
from repro.roofline.report import cell_report, corrected_costs, model_flops

HLO = """
ENTRY main {
  %x = bf16[4,1024,128]{2,1,0} parameter(0)
  %ag = bf16[64,1024,128]{2,1,0} all-gather(%x), replica_groups={}
  %ar = f32[512,512]{1,0} all-reduce(%y), to_apply=%add
  %rs = f32[32,512]{1,0} reduce-scatter(%z), to_apply=%add
  %aa = s8[1024,64]{1,0} all-to-all(%w)
  %cp = bf16[16,16]{1,0} collective-permute(%v)
  %ag2s = (bf16[8,8]{1,0}, bf16[8,8]{1,0}) all-gather-start(%q)
  %nothing = bf16[999,999]{1,0} add(%x, %x)
}
"""


def test_collective_bytes_sums_outputs():
    want = (64 * 1024 * 128 * 2      # all-gather bf16
            + 512 * 512 * 4          # all-reduce f32
            + 32 * 512 * 4           # reduce-scatter
            + 1024 * 64 * 1          # all-to-all s8
            + 16 * 16 * 2            # collective-permute
            + 8 * 8 * 2 * 2)         # async start tuple
    assert collective_bytes_from_hlo(HLO) == want


def test_collective_op_counts():
    counts = collective_op_counts(HLO)
    assert counts["all-gather"] == 2
    assert counts["all-reduce"] == 1
    assert "add" not in counts


def _rec(e1_flops=10.0, e2_flops=14.0, repeats=5, n_stacks=1):
    return {
        "arch": "qwen2.5-3b", "shape": "train_4k", "mesh": "single",
        "status": "ok", "n_devices": 256,
        "prod": {"flops": 1.0, "bytes_accessed": 1.0,
                 "collective_bytes": 1.0,
                 "memory": {"argument_size_in_bytes": 2 << 30,
                            "temp_size_in_bytes": 6 << 30}},
        "exact1": {"flops": e1_flops, "bytes_accessed": 8.0,
                   "collective_bytes": 2.0},
        "exact2": {"flops": e2_flops, "bytes_accessed": 10.0,
                   "collective_bytes": 2.5},
        "body_repeats": repeats, "n_stacks": n_stacks,
    }


def test_corrected_costs_formula():
    c = corrected_costs(_rec())
    assert c["flops"] == pytest.approx(10 + 4 * (14 - 10))   # e1 + (R-1)*body
    assert c["bytes_accessed"] == pytest.approx(8 + 4 * 2)
    c2 = corrected_costs(_rec(n_stacks=2))
    assert c2["flops"] == pytest.approx(10 + 4 * 4 / 2)


def test_cell_report_terms_and_dominant():
    r = cell_report(_rec())
    assert set(("compute_s", "memory_s", "collective_s",
                "dominant", "useful_flops_ratio", "fits_hbm")) <= set(r)
    assert r["dominant"] in ("compute", "memory", "collective")
    assert r["fits_hbm"] is True
    assert r["hbm_gib_per_device"] == pytest.approx(8.0)


def test_model_flops_kinds():
    train = model_flops("qwen2.5-3b", "train_4k")
    prefill = model_flops("qwen2.5-3b", "prefill_32k")
    decode = model_flops("qwen2.5-3b", "decode_32k")
    assert train == pytest.approx(6 * prefill / 2, rel=1e-6)  # same tokens
    assert decode < prefill / 1000                            # 1 token/seq
    # MoE uses ACTIVE params
    moe_train = model_flops("deepseek-v2-236b", "train_4k")
    from repro import configs
    cfg = configs.get_config("deepseek-v2-236b")
    assert moe_train == pytest.approx(
        6.0 * cfg.active_param_count() * 4096 * 256)
