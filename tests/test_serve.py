"""Continuous-batching engine: generations match a sequential reference."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import api
from repro.serve import Engine, Request


def _greedy_reference(cfg, params, prompt, max_new):
    toks = list(prompt)
    for _ in range(max_new):
        batch = {"tokens": jnp.asarray(toks, jnp.int32)[None]}
        logits, _ = api.prefill(params, cfg, batch, len(toks))
        toks.append(int(jnp.argmax(logits[0])))
    return toks[len(prompt):]


@pytest.mark.parametrize("arch", ["qwen2.5-3b", "mamba2-2.7b"])
def test_engine_matches_sequential_reference(arch):
    cfg = dataclasses.replace(configs.get_reduced(arch),
                              param_dtype="float32")
    params = api.init_params(cfg, jax.random.key(3))
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab, int(rng.integers(4, 12)))
               .astype(np.int32) for _ in range(3)]

    eng = Engine(cfg, params, slots=2, max_seq=64)
    for i, p in enumerate(prompts):
        eng.submit(Request(rid=i, prompt=p, max_new=4))
    finished = sorted(eng.run(), key=lambda r: r.rid)
    assert len(finished) == 3
    for req in finished:
        want = _greedy_reference(cfg, params, list(req.prompt), 4)
        assert req.generated == want, (req.rid, req.generated, want)


def test_slots_reused():
    cfg = dataclasses.replace(configs.get_reduced("qwen2.5-3b"),
                              param_dtype="float32")
    params = api.init_params(cfg, jax.random.key(0))
    eng = Engine(cfg, params, slots=1, max_seq=32)
    for i in range(3):
        eng.submit(Request(rid=i, prompt=np.array([1, 2, 3], np.int32),
                           max_new=2))
    finished = eng.run()
    assert len(finished) == 3
