"""Allclose sweeps for the paper's target kernel vs the pure-jnp oracle."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref


def _problem(rng, m, k, n, dtype):
    a32 = jnp.asarray(rng.standard_normal((m, k)), jnp.float32)
    b32 = jnp.asarray(rng.standard_normal((k, n)), jnp.float32)
    aq, a_s = ref.quantize_blockwise(a32, dtype)
    bq, b_s = ref.quantize_blockwise_2d(b32, dtype)
    return aq, bq, a_s, b_s


@pytest.mark.parametrize("m,k,n", [
    (128, 128, 128), (256, 384, 128), (128, 512, 256), (384, 256, 384),
])
@pytest.mark.parametrize("dtype", [jnp.float8_e4m3fn, jnp.int8])
def test_blocked_matches_ref(rng, m, k, n, dtype):
    aq, bq, a_s, b_s = _problem(rng, m, k, n, dtype)
    want = ref.scaled_gemm(aq, bq, a_s, b_s).astype(jnp.float32)
    got = ops.scaled_gemm(aq, bq, a_s, b_s, block_m=128, block_n=128,
                          block_k=128).astype(jnp.float32)
    scale = float(jnp.max(jnp.abs(want))) or 1.0
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=0.02 * scale)


@pytest.mark.parametrize("grid_order", ["mn", "nm"])
@pytest.mark.parametrize("scale_application", ["scale_acc", "dequant_inputs"])
def test_genome_axes_all_agree(rng, grid_order, scale_application):
    aq, bq, a_s, b_s = _problem(rng, 256, 256, 256, jnp.float8_e4m3fn)
    want = ref.scaled_gemm(aq, bq, a_s, b_s).astype(jnp.float32)
    got = ops.scaled_gemm(aq, bq, a_s, b_s, block_m=128, block_n=128,
                          block_k=128, grid_order=grid_order,
                          scale_application=scale_application
                          ).astype(jnp.float32)
    scale = float(jnp.max(jnp.abs(want)))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=0.02 * scale)


def test_unaligned_shapes_padded(rng):
    # M, N, K not multiples of the block: ops.py pads
    aq, bq, a_s, b_s = _problem(rng, 256, 256, 384, jnp.float8_e4m3fn)
    aq, a_s = aq[:200], a_s[:200]
    want = ref.scaled_gemm(aq, bq, a_s, b_s).astype(jnp.float32)
    got = ops.scaled_gemm(aq, bq, a_s, b_s, block_m=128, block_n=256,
                          block_k=128).astype(jnp.float32)
    assert got.shape == want.shape == (200, 384)
    scale = float(jnp.max(jnp.abs(want)))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=0.02 * scale)


def test_naive_monolith_matches(rng):
    from repro.kernels.scaled_gemm import naive_scaled_gemm
    aq, bq, a_s, b_s = _problem(rng, 128, 256, 128, jnp.float8_e4m3fn)
    want = ref.scaled_gemm(aq, bq, a_s, b_s).astype(jnp.float32)
    got = naive_scaled_gemm(aq, bq, a_s, b_s).astype(jnp.float32)
    scale = float(jnp.max(jnp.abs(want)))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=0.02 * scale)
