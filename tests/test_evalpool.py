"""Concurrent evaluation pool + content-addressed eval cache.

The acceptance scenarios of the eval-throughput layer:
  * cache accounting — duplicate submissions return the persisted verdict
    without consuming a platform slot, with hits/misses on the event log;
  * N-worker equivalence — a ``workers=3`` campaign produces a population
    bitwise-identical to the ``workers=1`` run (same seed), because
    benchmark jitter keys on ``sha256(source)``, not submission order;
  * kill-and-resume mid-pool-drain — a campaign killed while the pool is
    draining a generation resumes trajectory-identically;
  * fault soak at ``workers=3`` — the pooled loop survives >= 20% injected
    transient-failure rate with zero aborted generations (@slow).
"""
import json
import threading

import pytest

from repro.core.evalpool import (
    PRIORITY_PROBE, EvalCache, EvalPool,
)
from repro.core.evaluator import EvalResult, EvaluationService
from repro.core.llm import ScriptedLLM
from repro.core.resilience import (
    NO_WAIT_POLICY, FlakyLLM, FlakyService, RetryPolicy, ServiceBusyError,
    TransientError, retry_call,
)
from repro.core.scientist import KernelScientist
from repro.core import codegen
from repro.core.genome import SEED_MXU, SEED_NAIVE

SRC_OK = codegen.render_source(SEED_MXU, "pool test kernel")


def _fresh(seed=5, noise=0.05, **kw):
    return dict(llm=ScriptedLLM(seed=seed),
                service=EvaluationService(seed=seed, noise=noise),
                retry_policy=NO_WAIT_POLICY, **kw)


def _snapshot(sci):
    return {
        "trajectory": sci.trajectory(),
        "logbook": [l.to_dict() for l in sci.logbook],
        "population": [(r.rid, r.parents, r.status, r.timings_us)
                       for r in sci.population],
    }


# ---------------------------------------------------------------------------
# EvalCache
# ---------------------------------------------------------------------------
def test_cache_hit_miss_accounting(tmp_path):
    cache = EvalCache(tmp_path / "cache.jsonl")
    key = EvalCache.key_of("some kernel source")
    assert cache.get(key) is None
    assert (cache.hits, cache.misses) == (0, 1)
    cache.put(key, EvalResult("ok", timings_us={"m1_n1_k1": 2.5}))
    hit = cache.get(key)
    assert hit.status == "ok" and hit.timings_us == {"m1_n1_k1": 2.5}
    assert (cache.hits, cache.misses) == (1, 1)

    # persisted: a fresh cache on the same path reloads every verdict
    reloaded = EvalCache(tmp_path / "cache.jsonl")
    assert len(reloaded) == 1
    assert reloaded.get(key).timings_us == {"m1_n1_k1": 2.5}


def test_cache_skips_torn_tail_line(tmp_path):
    path = tmp_path / "cache.jsonl"
    good = json.dumps({"key": "k1", "status": "ok",
                       "timings_us": {"a": 1.0}})
    path.write_text(good + "\n" + '{"key": "k2", "status"')  # crash mid-append
    cache = EvalCache(path)
    assert len(cache) == 1 and cache.get("k1").status == "ok"


def test_pool_duplicate_submission_spares_platform_slot():
    svc = EvaluationService()
    with EvalPool([svc], cache=EvalCache(),
                  retry_policy=NO_WAIT_POLICY) as pool:
        first = pool.submit_async(SRC_OK)
        second_res = pool.submit(SRC_OK)     # duplicate: served from cache
        assert first.result().status == "ok"
        assert second_res.status == "ok"
        assert second_res.timings_us == first.result().timings_us
        assert svc.submissions == 1          # one platform slot consumed
        assert pool.cache.stats() == {"entries": 1, "hits": 1, "misses": 1}


def test_pool_streams_cache_events():
    from repro.core.events import EventLog
    events = EventLog()
    with EvalPool([EvaluationService()], cache=EvalCache(), events=events,
                  retry_policy=NO_WAIT_POLICY) as pool:
        pool.submit(SRC_OK, tag="00001")
        pool.submit(SRC_OK, tag="00009")
    outcomes = [(e["outcome"], e["tag"]) for e in events.select("eval_cache")]
    assert outcomes == [("miss", "00001"), ("hit", "00009")]
    assert all(e["key"] for e in events.select("eval_cache"))


# ---------------------------------------------------------------------------
# ServiceBusyError: typed busy signal, rerouted without backoff
# ---------------------------------------------------------------------------
def test_busy_service_raises_typed_error():
    svc = EvaluationService()
    svc._lock.acquire()
    try:
        with pytest.raises(ServiceBusyError, match="sequential"):
            svc.submit("x = 1")
    finally:
        svc._lock.release()
    assert issubclass(ServiceBusyError, TransientError)  # still retryable


def test_busy_retries_immediately_transient_backs_off():
    policy = RetryPolicy(base_delay_s=0.5, jitter=0.0)
    slept = []

    calls = []
    def busy_then_ok():
        calls.append(1)
        if len(calls) < 3:
            raise ServiceBusyError("worker occupied")
        return "ok"

    assert retry_call(busy_then_ok, policy=policy,
                      sleep=slept.append) == "ok"
    assert slept == []                       # rerouted, never backed off

    calls.clear()
    def flaky_then_ok():
        calls.append(1)
        if len(calls) < 2:
            raise TransientError("platform fault")
        return "ok"

    assert retry_call(flaky_then_ok, policy=policy,
                      sleep=slept.append) == "ok"
    assert slept == [0.5]                    # real faults still back off


# ---------------------------------------------------------------------------
# Evaluator memoization + content-keyed jitter
# ---------------------------------------------------------------------------
def test_problem_and_oracle_memoized_per_config_seed():
    svc = EvaluationService()
    cfg = svc.correctness_config
    p1 = svc._problem(cfg, seed=1234)
    want1 = svc._oracle(cfg, seed=1234)
    assert svc._problem(cfg, seed=1234) is p1        # same tuple object
    assert svc._oracle(cfg, seed=1234) is want1
    assert svc._problem(cfg, seed=7) is not p1       # distinct per seed
    # two submissions reuse one oracle: memo does not grow
    svc.submit(SRC_OK)
    n = len(svc._memo)
    svc.submit(SRC_OK + "# variant\n")
    assert len(svc._memo) == n


def test_jitter_keyed_on_content_not_submission_order():
    src_a = codegen.render_source(SEED_NAIVE, "a")
    src_b = codegen.render_source(SEED_MXU, "b")
    one = EvaluationService(noise=0.05, seed=7)
    one.submit(src_a)                        # shift the submission counter
    shifted = one.submit(src_b)
    fresh = EvaluationService(noise=0.05, seed=7).submit(src_b)
    assert shifted.timings_us == fresh.timings_us
    # a different platform seed still yields different noise
    other = EvaluationService(noise=0.05, seed=8).submit(src_b)
    assert other.timings_us != fresh.timings_us


def test_service_clone_shares_timing_seed():
    svc = EvaluationService(noise=0.05, seed=3, latency_s=0.0)
    clone = svc.clone()
    assert clone is not svc
    assert clone.submit(SRC_OK).timings_us == svc.submit(SRC_OK).timings_us


# ---------------------------------------------------------------------------
# Priority queue: campaign submissions outrank idle probes
# ---------------------------------------------------------------------------
class _GatedService:
    """First submission blocks on a gate so later queue entries pile up."""

    def __init__(self):
        self.entered = threading.Event()
        self.gate = threading.Event()
        self.order = []
        self.submissions = 0

    def submit(self, source):
        self.submissions += 1
        if source == "BLOCK":
            self.entered.set()
            assert self.gate.wait(timeout=30)
        self.order.append(source)
        return EvalResult("ok", timings_us={"m1_n1_k1": 1.0})


def test_probe_yields_to_campaign_submission():
    svc = _GatedService()
    pool = EvalPool([svc], retry_policy=NO_WAIT_POLICY)
    blocker = pool.submit_async("BLOCK")
    assert svc.entered.wait(timeout=30)      # worker is now occupied
    probe = pool.probe("PROBE")              # queued first...
    campaign = pool.submit_async("CAMPAIGN")  # ...but outranked
    svc.gate.set()
    for h in (blocker, campaign, probe):
        assert h.result(timeout=30).status == "ok"
    assert svc.order == ["BLOCK", "CAMPAIGN", "PROBE"]
    pool.close()


def test_pool_state_dict_accepts_legacy_single_service_state():
    pool = EvalPool.of(EvaluationService(), workers=2,
                       retry_policy=NO_WAIT_POLICY)
    pool.load_state_dict({"submissions": 7})          # pre-pool state.json
    assert pool.services[0].submissions == 7
    sd = pool.state_dict()
    assert [w["submissions"] for w in sd["workers"]] == [7, 0]
    pool2 = EvalPool.of(EvaluationService(), workers=2,
                        retry_policy=NO_WAIT_POLICY)
    pool2.load_state_dict(sd)
    assert pool2.submissions == 7


# ---------------------------------------------------------------------------
# N-worker equivalence (acceptance: 6 generations, noise=0.05)
# ---------------------------------------------------------------------------
def test_three_workers_reproduce_single_worker_campaign():
    one = KernelScientist(**_fresh())
    best1 = one.run(6)
    three = KernelScientist(**_fresh(workers=3))
    best3 = three.run(6)
    assert _snapshot(three) == _snapshot(one)
    assert best3.rid == best1.rid and best3.score == best1.score
    assert three.pool.stats()["workers"] == 3
    one.pool.close()
    three.pool.close()


# ---------------------------------------------------------------------------
# Kill-and-resume mid-pool-drain
# ---------------------------------------------------------------------------
class _Counter:
    def __init__(self):
        self.lock = threading.Lock()
        self.n = 0


class _SharedCrashService:
    """Raises KeyboardInterrupt (a real kill) on the n-th submission across
    the whole pool — whichever worker happens to draw it."""

    def __init__(self, inner, counter, crash_at):
        self.inner = inner
        self.counter = counter
        self.crash_at = crash_at

    def submit(self, source):
        with self.counter.lock:
            self.counter.n += 1
            n = self.counter.n
        if n == self.crash_at:
            raise KeyboardInterrupt
        return self.inner.submit(source)

    def clone(self):
        return _SharedCrashService(self.inner.clone(), self.counter,
                                   self.crash_at)

    def __getattr__(self, name):
        return getattr(self.inner, name)


def test_kill_and_resume_mid_pool_drain_workers3(tmp_path):
    ref = KernelScientist(**_fresh(workers=3))
    ref.run(6)

    kw = _fresh(workers=3)
    kw["service"] = _SharedCrashService(kw["service"], _Counter(), crash_at=8)
    sci = KernelScientist(**kw, workdir=tmp_path / "wd")
    with pytest.raises(KeyboardInterrupt):
        sci.run(6)
    sci.pool.close()                         # quiesce the surviving workers
    assert len(sci.logbook) < 6              # the campaign really was cut

    resumed = KernelScientist.resume(tmp_path / "wd", **_fresh(workers=3))
    resumed.run(6 - len(resumed.logbook))
    assert _snapshot(resumed) == _snapshot(ref)
    ref.pool.close()
    resumed.pool.close()


def test_resumed_campaign_serves_reprobes_from_cache(tmp_path):
    sci = KernelScientist(**_fresh(), workdir=tmp_path / "wd")
    sci.run(3)
    sci.pool.close()
    assert (tmp_path / "wd" / "eval_cache.jsonl").exists()

    resumed = KernelScientist.resume(tmp_path / "wd", **_fresh())
    before = resumed.pool.submissions
    handles = [resumed.pool.probe(r.source, tag=r.rid)
               for r in resumed.population]
    results = [h.result() for h in handles]
    assert all(r.status in ("ok", "compile_error", "runtime_error",
                            "incorrect") for r in results)
    assert resumed.pool.cache.hits == len(results) > 0
    assert resumed.pool.submissions == before     # zero platform slots
    # re-probed timings match what the campaign recorded
    for rec in resumed.population:
        if rec.status == "ok":
            probe = resumed.pool.submit(rec.source)
            assert probe.timings_us == rec.timings_us
    resumed.pool.close()


# ---------------------------------------------------------------------------
# Fault-injection stress at workers=3
# ---------------------------------------------------------------------------
@pytest.mark.slow
def test_stress_20pct_faults_workers3_completes_10_generations():
    llm = FlakyLLM(ScriptedLLM(seed=11), seed=13,
                   error_rate=0.10, timeout_rate=0.04, malformed_rate=0.06)
    service = FlakyService(EvaluationService(seed=11), seed=17,
                           error_rate=0.20)
    sci = KernelScientist(llm=llm, service=service, workers=3,
                          retry_policy=NO_WAIT_POLICY)
    best = sci.run(10)

    assert len(sci.logbook) == 10            # zero aborted generations
    assert all(len(log.submitted) == 3 for log in sci.logbook)
    assert len(sci.population) == 3 + 30
    assert best is not None and best.score < float("inf")
    # the pool really had 3 independent fault streams under fire
    fault_seeds = [s.seed for s in sci.pool.services]
    assert fault_seeds == [17, 18, 19]
    assert sum(s.faults for s in sci.pool.services) > 0
    assert sci.events.counts().get("retry", 0) > 0
    traj = [v for _, v in sci.trajectory() if v is not None]
    assert traj == sorted(traj, reverse=True)  # still monotone best-so-far
    sci.pool.close()
