"""Verdict trust (``core.integrity``): timing audits with quorum
re-measurement, poison-kernel quarantine, per-worker drift canaries,
circuit breakers, and campaign budgets.

The acceptance scenarios of the integrity layer:
  * corrupted-timing recovery — a campaign whose evaluation backend
    silently corrupts >= 10% of verdict timings converges to the same best
    kernel as a clean run, because the auditor's salted quorum re-measures
    every improbable verdict;
  * poison-kernel containment — a kernel that kills its worker every time
    it runs costs the campaign exactly ``quarantine_after`` worker deaths
    total (not ``max_requeues`` per rediscovery), and the campaign still
    converges to the clean run's best genome;
  * kill-and-resume with audits in flight — a campaign killed in the
    middle of a re-measure quorum resumes to a trajectory bitwise
    identical to an uninterrupted run (quorum samples are content-keyed
    and cached).
"""
import json
import os
import pathlib

import pytest

from repro.core import codegen
from repro.core.evaluator import EvalResult, EvaluationService
from repro.core.genome import SEED_MXU
from repro.core.evalpool import EvalCache, EvalPool
from repro.core.events import EventLog
from repro.core.integrity import (
    CanaryController, HealthMonitor, Integrity, Quarantine, TimingAuditor,
)
from repro.core.llm import ScriptedLLM
from repro.core.resilience import (
    NO_WAIT_POLICY, POISON_MARKER, CircuitBreaker, CircuitOpenError,
    CorruptTimingService, DriftService, PoisonService, TransientError,
)
from repro.core.scientist import KernelScientist
from repro.core.transport import InProcessTransport, WorkerDiedError


# ---------------------------------------------------------------------------
# CircuitBreaker
# ---------------------------------------------------------------------------
def test_breaker_state_machine():
    brk = CircuitBreaker(failure_threshold=2, cooldown_calls=3)
    assert brk.state == "closed" and brk.allow()
    brk.record_failure()
    assert brk.state == "closed" and brk.allow()   # below threshold
    brk.record_failure()
    assert brk.state == "open" and brk.trips == 1
    assert not brk.allow() and not brk.allow()     # cooldown ticks 1, 2
    assert brk.allow()                             # tick 3: half-open probe
    assert brk.state == "half_open"
    assert not brk.allow()                         # one probe in flight only
    brk.record_failure()                           # probe failed
    assert brk.state == "open" and brk.skips == 0  # cooldown restarted
    for _ in range(3):
        brk.allow()
    assert brk.state == "half_open"
    brk.record_success()                           # probe succeeded
    assert brk.state == "closed" and brk.failures == 0
    assert brk.allow()


def test_breaker_state_roundtrip():
    brk = CircuitBreaker(failure_threshold=1, cooldown_calls=5)
    brk.record_failure()
    brk.allow()
    fresh = CircuitBreaker(failure_threshold=1, cooldown_calls=5)
    fresh.load_state_dict(brk.state_dict())
    assert fresh.state_dict() == brk.state_dict()
    assert fresh.state == "open" and fresh.skips == 1 and fresh.trips == 1


def test_circuit_open_error_is_not_retryable():
    # retry_call must not burn its backoff schedule on a refused call
    assert not isinstance(CircuitOpenError("open"), TransientError)


# ---------------------------------------------------------------------------
# TimingAuditor
# ---------------------------------------------------------------------------
def test_auditor_flags_no_lineage_and_improbable_jumps():
    aud = TimingAuditor(quorum_k=3)
    assert aud.flag(300.0, None)                  # seeds: always re-measured
    assert aud.flag(0.0, 300.0)                   # degenerate geomean
    assert aud.flag(300.0, 290.0) is None         # ordinary step: trusted
    assert aud.flag(150.0, 290.0) is None         # 2x win: plausible
    assert aud.flag(1500.0, 300.0)                # 5x: the corruption factor
    assert aud.flag(60.0, 300.0)                  # 5x in either direction


def test_auditor_salt_changes_hash_not_genome():
    src = "def k():\n    pass\n"
    salts = [TimingAuditor.salted(src, i) for i in range(1, 4)]
    assert len({EvalCache.key_of(s) for s in salts + [src]}) == 4
    for s in salts:
        assert s.startswith(src)                  # trailing comment only
        assert "# integrity-quorum sample" in s


def _ok(timings):
    return EvalResult("ok", timings_us=timings)


def test_auditor_merge_confirms_close_originals():
    aud = TimingAuditor(quorum_k=3)
    orig = _ok({"a": 100.0, "b": 200.0})
    samples = [_ok({"a": 101.0, "b": 199.0}), _ok({"a": 99.5, "b": 201.0}),
               _ok({"a": 100.5, "b": 200.5})]
    final, corrected = aud.merge(orig, samples)
    assert final is orig and not corrected        # kept bit-for-bit
    assert aud.quorums == 1 and aud.corrected == 0


def test_auditor_merge_corrects_outlier_to_sample_medians():
    aud = TimingAuditor(quorum_k=3)
    orig = _ok({"a": 500.0, "b": 1000.0})         # 5x corrupted
    samples = [_ok({"a": 101.0, "b": 199.0}), _ok({"a": 99.0, "b": 201.0}),
               _ok({"a": 100.0, "b": 200.0})]
    final, corrected = aud.merge(orig, samples)
    assert corrected and aud.corrected == 1
    assert final.timings_us == {"a": 100.0, "b": 200.0}  # per-config medians
    assert final.status == "ok"


def test_auditor_merge_keeps_original_without_usable_samples():
    aud = TimingAuditor(quorum_k=3)
    orig = _ok({"a": 500.0})
    final, corrected = aud.merge(orig, [None, EvalResult("failed", "boom")])
    assert final is orig and not corrected


# ---------------------------------------------------------------------------
# Quarantine / CanaryController / HealthMonitor units
# ---------------------------------------------------------------------------
def test_quarantine_blocks_after_k_deaths():
    q = Quarantine(after_k=2)
    assert q.record_death("k1", "segfault") == 1
    assert q.blocked("k1") is None and len(q) == 0
    assert q.record_death("k1", "segfault") == 2
    assert q.blocked("k1") == "segfault" and len(q) == 1
    assert q.blocked("k2") is None and q.deaths("k1") == 2
    fresh = Quarantine(after_k=2)
    fresh.load_state_dict(q.state_dict())
    assert fresh.blocked("k1") == "segfault" and fresh.deaths("k1") == 2


def test_canary_reference_then_drift():
    c = CanaryController(interval=2, tolerance=0.25)
    assert c.due(2) and c.due(4) and not c.due(3)
    assert c.check(400.0) == "baseline" and c.reference_us == 400.0
    assert c.check(420.0) == "ok"                 # within 25%
    assert c.check(600.0) == "drift"              # 1.5x
    assert c.check(None) == "drift"               # dead worker
    assert c.runs == 4 and c.drifts == 2
    fresh = CanaryController(interval=2, tolerance=0.25)
    fresh.load_state_dict(c.state_dict())
    assert fresh.reference_us == 400.0 and fresh.drifts == 2


def test_health_budgets_and_accumulated_wall_clock():
    t = [0.0]
    mon = HealthMonitor(max_wall_clock_s=100.0, max_submissions=10,
                        clock=lambda: t[0])
    mon.start()
    assert mon.budget_exceeded(9) is None
    assert "submission budget" in mon.budget_exceeded(10)
    t[0] = 60.0
    assert mon.budget_exceeded(0) is None and mon.elapsed_s == 60.0
    # kill + resume: consumed wall-clock carries over
    fresh = HealthMonitor(max_wall_clock_s=100.0, clock=lambda: t[0])
    fresh.load_state_dict(mon.state_dict())
    t[0] = 0.0
    fresh.start()
    t[0] = 40.0
    assert fresh.elapsed_s == 100.0
    assert "wall-clock budget" in fresh.budget_exceeded(0)
    events = EventLog()
    fresh.snapshot(events, generation=3)
    (snap,) = events.select("health")
    assert snap["elapsed_s"] == 100.0 and snap["generation"] == 3


def test_integrity_defaults_are_all_off():
    integ = Integrity()
    assert not integ.enabled
    assert integ.auditor is None and integ.quarantine is None
    assert integ.canary is None and integ.health is None
    assert integ.llm_breaker is None and integ.eval_breaker is None
    integ.load_state_dict(integ.state_dict())     # no-op round-trip


def test_integrity_state_roundtrip():
    integ = Integrity(quorum_k=3, quarantine_after=2, canary_interval=1,
                      budget_submissions=100, breaker_failures=2)
    assert integ.enabled
    integ.auditor.flags = 4
    integ.quarantine.record_death("k", "dead")
    integ.canary.check(300.0)
    integ.llm_breaker.record_failure()
    fresh = Integrity(quorum_k=3, quarantine_after=2, canary_interval=1,
                      budget_submissions=100, breaker_failures=2)
    fresh.load_state_dict(integ.state_dict())
    assert fresh.auditor.flags == 4
    assert fresh.quarantine.deaths("k") == 1
    assert fresh.canary.reference_us == 300.0
    assert fresh.llm_breaker.failures == 1
    assert fresh.state_dict() == integ.state_dict()


# ---------------------------------------------------------------------------
# CorruptTimingService: content-keyed, worker-independent corruption
# ---------------------------------------------------------------------------
def test_corruption_is_a_property_of_the_source_not_the_call():
    svc = CorruptTimingService(EvaluationService(seed=3, noise=0.0),
                               seed=9, corrupt_rate=0.5)
    sources = [codegen.render_source(SEED_MXU, f"variant {i}")
               + f"\n# variant {i}\n" for i in range(8)]
    first = {s: svc.submit(s).timings_us for s in sources}
    again = {s: svc.submit(s).timings_us for s in sources}
    assert first == again                          # same draw every call
    clone = svc.clone()                            # SAME seed on purpose
    assert {s: clone.submit(s).timings_us for s in sources} == first
    # the configured rate really corrupts some and spares others
    clean = EvaluationService(seed=3, noise=0.0)
    truth = {s: clean.submit(s).timings_us for s in sources}
    corrupted = [s for s in sources if first[s] != truth[s]]
    assert corrupted and len(corrupted) < len(sources)
    assert svc.corruptions == 2 * len(corrupted)


# ---------------------------------------------------------------------------
# Pool-level quarantine: deaths capped at K, resubmission blocked
# ---------------------------------------------------------------------------
class _MarkerDeathTransport(InProcessTransport):
    """In-process stand-in for a poison kernel: raises WorkerDiedError
    whenever the source carries the poison marker (the real PoisonService
    ``os._exit``s, which only the subprocess transport survives)."""

    def __init__(self, services, marker=POISON_MARKER):
        super().__init__(services)
        self.marker = marker
        self.poison_deaths = 0

    def run(self, idx, source):
        if self.marker in source:
            self.poison_deaths += 1
            self._emit("worker_died", worker=idx, reason="poison kernel",
                       transport=self.kind)
            raise WorkerDiedError(f"poison death #{self.poison_deaths}")
        return super().run(idx, source)


def test_quarantine_caps_worker_deaths_per_poison_hash():
    events = EventLog()
    transport = _MarkerDeathTransport([EvaluationService(seed=0, noise=0.0)])
    pool = EvalPool(transport=transport, events=events,
                    retry_policy=NO_WAIT_POLICY, max_requeues=50,
                    quarantine=Quarantine(after_k=2))
    poison = f"# {POISON_MARKER}\nx = 1\n"

    res = pool.submit_async(poison).result(timeout=30)
    assert res.status == "quarantined"
    assert transport.poison_deaths == 2           # exactly K, not 50
    assert len(events.select("quarantine_add")) == 1

    # rediscovery costs zero further deaths: blocked at submit time
    res2 = pool.submit_async(poison).result(timeout=30)
    assert res2.status == "quarantined"
    assert transport.poison_deaths == 2
    assert len(events.select("quarantine_block")) == 1
    # a healthy kernel still flows normally through the same pool
    healthy = codegen.render_source(SEED_MXU, "healthy")
    assert pool.submit_async(healthy).result(timeout=30).status == "ok"
    pool.close()


def test_busy_reroutes_do_not_count_as_requeues():
    from repro.core.resilience import ServiceBusyError

    attempts = NO_WAIT_POLICY.max_attempts

    class _BusyTransport(InProcessTransport):
        def __init__(self, services, busy):
            super().__init__(services)
            self.busy = busy
            self.calls = 0

        def run(self, idx, source):
            self.calls += 1
            if self.calls <= self.busy:
                raise ServiceBusyError("another submission in flight")
            return super().run(idx, source)

    events = EventLog()
    # exactly one full retry schedule of busy answers: the pool must
    # reroute (put the job back on the queue) rather than burn a requeue
    transport = _BusyTransport([EvaluationService(seed=0, noise=0.0)],
                               busy=attempts)
    pool = EvalPool(transport=transport, events=events,
                    retry_policy=NO_WAIT_POLICY)
    handle = pool.submit_async(codegen.render_source(SEED_MXU, "busy probe"))
    assert handle.result(timeout=30).status == "ok"
    assert handle.busy_reroutes == 1
    assert handle.requeues == 0                   # requeues = worker deaths
    assert len(events.select("busy_reroute")) == 1
    assert not events.select("worker_requeue")
    pool.close()


# ---------------------------------------------------------------------------
# Campaign-level: corrupted timings are audited back to the clean optimum
# ---------------------------------------------------------------------------
SEED = 7
CORRUPT_SEED = 23          # content-keyed; chosen so the 6-generation
GENS = 6                   # campaign sees corruption in gens 1+ as well


def _clean_campaign():
    sci = KernelScientist(
        llm=ScriptedLLM(seed=SEED),
        backend=EvalPool.of(EvaluationService(seed=SEED, noise=0.0),
                            retry_policy=NO_WAIT_POLICY),
        retry_policy=NO_WAIT_POLICY)
    sci.run(GENS)
    return sci


@pytest.fixture(scope="module")
def clean_run():
    return _clean_campaign()


def test_corrupted_timings_audited_back_to_clean_campaign(clean_run):
    corrupt = CorruptTimingService(EvaluationService(seed=SEED, noise=0.0),
                                   seed=CORRUPT_SEED, corrupt_rate=0.12)
    integ = Integrity(quorum_k=3)
    sci = KernelScientist(
        llm=ScriptedLLM(seed=SEED),
        backend=EvalPool.of(corrupt, retry_policy=NO_WAIT_POLICY),
        retry_policy=NO_WAIT_POLICY, integrity=integ)
    best = sci.run(GENS)

    assert corrupt.corruptions > 0                # faults really happened
    assert integ.auditor.flags >= 3               # seeds + corrupted children
    assert integ.auditor.corrected > 0            # and were overruled
    # zero-noise platform: every corrected verdict recovers the exact
    # clean timings, so the whole campaign is bit-identical to the clean run
    clean_best = clean_run.population.best()
    assert best.rid == clean_best.rid
    assert best.genome.describe() == clean_best.genome.describe()
    assert [(r.rid, r.status, r.timings_us) for r in sci.population] == \
           [(r.rid, r.status, r.timings_us) for r in clean_run.population]
    assert sci.events.counts().get("audit_quorum", 0) == integ.auditor.quorums


def _poison_target(clean_run):
    """The poison kernel: the worst non-best, non-ancestor-of-best child —
    a loser branch, so quarantining it must not change the winner."""
    best = clean_run.population.best()
    ancestors, frontier = set(), list(best.parents)
    while frontier:
        rid = frontier.pop()
        if rid in ancestors:
            continue
        ancestors.add(rid)
        frontier.extend(clean_run.population.get(rid).parents)
    losers = [r for r in clean_run.population
              if r.generation >= 1 and r.rid != best.rid
              and r.rid not in ancestors and r.status == "ok"]
    return max(losers, key=lambda r: (r.score, r.rid))


class _PoisonLLM:
    """Wrap an LLM and append the poison marker to writer replies whose
    source matches ``target`` — the recurring poison kernel: every time
    evolution (re)writes this kernel, the submitted source wedges its
    worker."""

    def __init__(self, inner, target: str):
        self.inner = inner
        self.target = target
        self.poisoned = 0

    def complete(self, prompt: str) -> str:
        out = self.inner.complete(prompt)
        try:
            reply = json.loads(out)
        except ValueError:
            return out
        if isinstance(reply, dict) and reply.get("source") == self.target:
            reply["source"] += f"\n# {POISON_MARKER}\n"
            self.poisoned += 1
            return json.dumps(reply)
        return out

    def __getattr__(self, name):              # incl. state_dict passthrough
        return getattr(self.inner, name)


def test_poison_kernel_quarantined_campaign_converges_to_clean_best(
        clean_run):
    """The headline acceptance run: 12% corrupted timings AND a recurring
    worker-killing kernel; the campaign must finish all generations, cap
    the poison kernel's worker deaths at ``quarantine_after``, tell the
    designer about the quarantined genome, and still converge to the clean
    run's best kernel."""
    target = _poison_target(clean_run)
    llm = _PoisonLLM(ScriptedLLM(seed=SEED), target.source)
    designer_prompts = []
    real_complete = llm.complete

    def spying_complete(prompt):
        if '"stage": "designer"' in prompt:
            designer_prompts.append(prompt)
        return real_complete(prompt)

    llm.complete = spying_complete
    corrupt = CorruptTimingService(EvaluationService(seed=SEED, noise=0.0),
                                   seed=CORRUPT_SEED, corrupt_rate=0.12)
    transport = _MarkerDeathTransport([corrupt])
    integ = Integrity(quorum_k=3, quarantine_after=2)
    sci = KernelScientist(
        llm=llm,
        backend=EvalPool(transport=transport, retry_policy=NO_WAIT_POLICY),
        retry_policy=NO_WAIT_POLICY, integrity=integ)
    best = sci.run(GENS)

    assert llm.poisoned >= 1                      # the poison really recurred
    assert len(sci.logbook) == GENS               # zero aborted generations
    quarantined = sci.population.quarantined_records()
    assert len(quarantined) == 1
    assert POISON_MARKER in quarantined[0].source
    assert transport.poison_deaths == 2           # capped at K total
    assert len(integ.quarantine) == 1
    # the designer is told which genomes are radioactive
    assert any("Quarantined kernels" in p for p in designer_prompts)
    # and the campaign still finds the clean optimum
    clean_best = clean_run.population.best()
    assert best.genome.describe() == clean_best.genome.describe()
    assert best.score == clean_best.score
    counts = sci.events.counts()
    assert counts.get("quarantine_add", 0) == 1
    assert corrupt.corruptions > 0 and integ.auditor.corrected > 0


# ---------------------------------------------------------------------------
# Kill-and-resume mid-quorum: trajectory identity
# ---------------------------------------------------------------------------
def _snapshot(sci):
    return {
        "trajectory": sci.trajectory(),
        "logbook": [l.to_dict() for l in sci.logbook],
        "population": [(r.rid, r.parents, r.status, r.timings_us)
                       for r in sci.population],
    }


class _SaltCrashService:
    """Raises KeyboardInterrupt (a real SIGINT/OOM kill) on the n-th
    *quorum-sample* submission — the campaign dies in the middle of a
    re-measure quorum, with some samples cached and some never run."""

    def __init__(self, inner, crash_at_salt):
        self.inner = inner
        self.crash_at_salt = crash_at_salt
        self.salts = 0

    def submit(self, source):
        if "integrity-quorum sample" in source:
            self.salts += 1
            if self.salts == self.crash_at_salt:
                raise KeyboardInterrupt
        return self.inner.submit(source)

    def __getattr__(self, name):
        return getattr(self.inner, name)


def _quorum_campaign(tmp_path, name, service):
    wd = tmp_path / name
    return KernelScientist(
        llm=ScriptedLLM(seed=SEED),
        backend=EvalPool.of(service, cache=EvalCache(wd / "eval_cache.jsonl"),
                            retry_policy=NO_WAIT_POLICY),
        retry_policy=NO_WAIT_POLICY, workdir=wd,
        integrity=Integrity(quorum_k=3))


def _corrupt_service():
    # noise > 0 so quorum samples are genuinely distinct draws (the
    # content-keyed jitter is what makes the replay exact), corruption so
    # generations past the seeds get flagged and quorumed too
    return CorruptTimingService(EvaluationService(seed=SEED, noise=0.05),
                                seed=CORRUPT_SEED, corrupt_rate=0.12)


def test_kill_mid_quorum_resumes_to_identical_trajectory(tmp_path):
    ref = _quorum_campaign(tmp_path, "ref", _corrupt_service())
    ref.run(GENS)
    assert ref.integrity.auditor.quorums > 3      # quorums beyond the seeds

    # salts 1-9 belong to the three always-audited seeds; salt 11 lands in
    # the middle of a generation-1 quorum (one sample cached, one in
    # flight, one never submitted)
    crash = _quorum_campaign(
        tmp_path, "wd", _SaltCrashService(_corrupt_service(), crash_at_salt=11))
    with pytest.raises(KeyboardInterrupt):
        crash.run(GENS)
    crash.pool.close(wait=False)
    done = len(crash.logbook)
    assert done < GENS

    resumed = KernelScientist.resume(
        tmp_path / "wd", llm=ScriptedLLM(seed=SEED),
        backend=EvalPool.of(_corrupt_service(),
                            cache=EvalCache(tmp_path / "wd"
                                            / "eval_cache.jsonl"),
                            retry_policy=NO_WAIT_POLICY),
        retry_policy=NO_WAIT_POLICY, integrity=Integrity(quorum_k=3))
    resumed.run(GENS - done)
    assert _snapshot(resumed) == _snapshot(ref)
    # the completed quorum samples replayed from the cache, not the platform
    assert resumed.pool.stats()["cache_hits"] > 0


def test_kill_mid_seed_quorum_restarts_to_identical_trajectory(tmp_path):
    ref = _quorum_campaign(tmp_path, "ref", _corrupt_service())
    ref.run(3)

    # salt 5 is inside the second seed's quorum: the campaign dies before
    # seeding completes (state.json says seeded=False), so resume restarts
    # from scratch — but every already-measured verdict and quorum sample
    # replays as a cache hit
    crash = _quorum_campaign(
        tmp_path, "wd", _SaltCrashService(_corrupt_service(), crash_at_salt=5))
    with pytest.raises(KeyboardInterrupt):
        crash.run(3)
    crash.pool.close(wait=False)

    resumed = KernelScientist.resume(
        tmp_path / "wd", llm=ScriptedLLM(seed=SEED),
        backend=EvalPool.of(_corrupt_service(),
                            cache=EvalCache(tmp_path / "wd"
                                            / "eval_cache.jsonl"),
                            retry_policy=NO_WAIT_POLICY),
        retry_policy=NO_WAIT_POLICY, integrity=Integrity(quorum_k=3))
    assert resumed.events.select("resume")[0]["mode"] == "restart_unseeded"
    resumed.run(3)
    assert _snapshot(resumed) == _snapshot(ref)


# ---------------------------------------------------------------------------
# Canary sentinel: drift detection, respawn, re-measurement
# ---------------------------------------------------------------------------
def _drift_campaign(drift_after):
    # call schedule at workers=1, canary every generation, no quorum:
    # seeds = calls 1-3, gen1 = 4-6, gen1 canary = 7 (clean reference),
    # gen2 = 8-10, gen2 canary = 11 — drift_after=7 skews all of gen2
    svc = DriftService(EvaluationService(seed=SEED, noise=0.0),
                       drift_after=drift_after, drift_factor=1.6)
    sci = KernelScientist(
        llm=ScriptedLLM(seed=SEED),
        backend=EvalPool.of(svc, cache=EvalCache(None),
                            retry_policy=NO_WAIT_POLICY),
        retry_policy=NO_WAIT_POLICY,
        integrity=Integrity(canary_interval=1))
    sci.run(3)
    return sci


def test_canary_detects_drift_respawns_and_remeasures():
    steady = _drift_campaign(drift_after=0)       # never drifts
    drifted = _drift_campaign(drift_after=7)

    counts = drifted.events.counts()
    assert counts["worker_drift"] == 1
    assert counts["worker_respawn"] == 1
    # every generation-2 verdict came from the drifted worker: all three
    # are invalidated (cache tombstones) and re-measured on the respawn
    assert counts["verdict_invalidated"] == 3
    canaries = drifted.events.select("canary")
    assert [c["verdict"] for c in canaries if "verdict" in c] == \
           ["baseline", "drift", "ok"]
    assert drifted.integrity.canary.reference_us == \
        steady.integrity.canary.reference_us
    # the re-measured campaign lands exactly where the steady one did
    assert [(r.rid, r.status, r.timings_us) for r in drifted.population] == \
           [(r.rid, r.status, r.timings_us) for r in steady.population]
    assert _snapshot(drifted)["trajectory"] == _snapshot(steady)["trajectory"]
    assert not steady.events.select("worker_drift")


# ---------------------------------------------------------------------------
# Budgets and breakers inside the campaign loop
# ---------------------------------------------------------------------------
def test_submission_budget_stops_at_generation_boundary():
    sci = KernelScientist(
        llm=ScriptedLLM(seed=SEED),
        backend=EvalPool.of(EvaluationService(seed=SEED, noise=0.0),
                            retry_policy=NO_WAIT_POLICY),
        retry_policy=NO_WAIT_POLICY,
        integrity=Integrity(budget_submissions=5))
    best = sci.run(10)
    # seeds (3 submissions) fit the budget, generation 1 (3 more) exceeds
    # it — checked at the boundary, so generation 2 never starts
    assert len(sci.logbook) == 1
    assert best is not None                       # stopped, not aborted
    (stop,) = sci.events.select("budget_stop")
    assert "submission budget" in stop["reason"] and stop["generation"] == 2
    assert len(sci.events.select("health")) == 1  # one snapshot per gen


class _DeadLLM:
    def complete(self, prompt):
        raise TransientError("llm api down")


def test_llm_breaker_skips_straight_to_fallbacks():
    sci = KernelScientist(
        llm=_DeadLLM(),
        backend=EvalPool.of(EvaluationService(seed=SEED, noise=0.0),
                            retry_policy=NO_WAIT_POLICY),
        retry_policy=NO_WAIT_POLICY,
        integrity=Integrity(breaker_failures=2, breaker_cooldown=4))
    sci.run(3)
    assert len(sci.logbook) == 3                  # rule-based campaign
    breaker = sci.events.select("breaker")
    assert any(b.get("transition") == "closed->open" for b in breaker)
    skips = [b for b in breaker if b.get("action") == "skip"]
    assert skips                                  # open circuit refused calls
    # refused stages paid zero retries: far fewer than every-stage-retries
    stages = len(sci.events.select("stage_start"))
    retries = sci.events.counts().get("retry", 0)
    assert retries < stages * (NO_WAIT_POLICY.max_attempts - 1)
    assert sci.events.counts()["fallback"] == stages


class _BrokenService:
    """Non-transient platform failure: every submission raises."""

    def __init__(self):
        self.submissions = 0

    def submit(self, source):
        self.submissions += 1
        raise RuntimeError("evaluation platform rejected the submission")


def test_eval_breaker_prefails_submissions_when_platform_is_down():
    sci = KernelScientist(
        llm=ScriptedLLM(seed=SEED),
        backend=EvalPool.of(_BrokenService(), retry_policy=NO_WAIT_POLICY),
        retry_policy=NO_WAIT_POLICY,
        integrity=Integrity(breaker_failures=2, breaker_cooldown=8))
    best = sci.run(0)                             # seeds only
    assert best is None
    assert [r.status for r in sci.population] == ["failed"] * 3
    # the seeds were all enqueued while the breaker was still closed, so
    # all three reached the (dead) platform and tripped it open
    assert sci.pool.submissions == 3
    breaker = sci.events.select("breaker")
    assert any(b.get("transition") == "closed->open" and b.get("name") == "eval"
               for b in breaker)
    # every subsequent submission is refused up front: a pre-failed handle,
    # zero further platform traffic
    handle = sci._submit_record(codegen.render_source(SEED_MXU, "probe"),
                                tag="probe")
    with pytest.raises(CircuitOpenError):
        handle.result(timeout=5)
    assert sci.pool.submissions == 3
    skips = [b for b in sci.events.select("breaker")
             if b.get("action") == "skip" and b.get("name") == "eval"]
    assert len(skips) == 1


# ---------------------------------------------------------------------------
# @slow soak: subprocess workers, real poison kills, corrupted timings
# ---------------------------------------------------------------------------
@pytest.mark.slow
def test_soak_subprocess_poison_and_corruption_campaign(tmp_path):
    """The integrity layer under the real failure stack: subprocess
    workers, ``PoisonService`` hard-killing (``os._exit``) any worker that
    runs a marked kernel, and 12% content-keyed timing corruption.  The
    campaign must finish every generation, quarantine the poison kernel
    after exactly K deaths, and converge to the clean in-process best.

    Artifacts (``events.jsonl`` with the audit/quarantine chronicle) land
    in ``INTEGRITY_SOAK_DIR`` when set, so CI uploads them on failure."""
    soak_dir = pathlib.Path(os.environ.get("INTEGRITY_SOAK_DIR",
                                           tmp_path)).resolve()
    soak_dir.mkdir(parents=True, exist_ok=True)

    clean = _clean_campaign()
    target = _poison_target(clean)

    wd = soak_dir / "campaign"
    service = PoisonService(
        CorruptTimingService(EvaluationService(seed=SEED, noise=0.0),
                             seed=CORRUPT_SEED, corrupt_rate=0.12))
    integ = Integrity(quorum_k=3, quarantine_after=2)
    sci = KernelScientist(
        llm=_PoisonLLM(ScriptedLLM(seed=SEED), target.source),
        backend=EvalPool.of(service, workers=2,
                            cache=EvalCache(wd / "eval_cache.jsonl"),
                            retry_policy=NO_WAIT_POLICY,
                            transport="subprocess"),
        retry_policy=NO_WAIT_POLICY, workdir=wd, integrity=integ)
    try:
        best = sci.run(GENS)
    finally:
        sci.pool.close(wait=False)

    assert len(sci.logbook) == GENS
    quarantined = sci.population.quarantined_records()
    assert len(quarantined) == 1
    assert POISON_MARKER in quarantined[0].source
    assert len(integ.quarantine) == 1
    # the poison hash cost exactly K real worker processes, no more
    key = EvalCache.key_of(quarantined[0].source)
    assert integ.quarantine.deaths(key) == 2
    deaths = sci.events.select("worker_died")
    assert len(deaths) >= 2
    clean_best = clean.population.best()
    assert best.genome.describe() == clean_best.genome.describe()
    assert best.score == clean_best.score
    assert (wd / "events.jsonl").exists()         # the CI post-mortem trail
