"""Checkpointing: atomicity, resume, async, elastic re-shard, kill/restart."""
import os
import signal
import subprocess
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import Checkpointer


def _tree(seed=0):
    k = jax.random.key(seed)
    return {
        "a": jax.random.normal(k, (8, 16), jnp.float32),
        "nested": {"b": jnp.arange(12, dtype=jnp.int32).reshape(3, 4),
                   "c": jnp.ones((5,), jnp.bfloat16)},
    }


def test_roundtrip_bit_exact(tmp_path):
    ck = Checkpointer(tmp_path)
    t = _tree()
    ck.save(7, t)
    assert ck.latest_step() == 7
    restored = ck.restore(7, jax.eval_shape(lambda: t))
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_async_save_and_gc(tmp_path):
    ck = Checkpointer(tmp_path, keep=2)
    for s in (1, 2, 3):
        ck.save_async(s, _tree(s))
    ck.wait()
    assert ck.all_steps() == [2, 3]


def test_uncommitted_checkpoint_ignored(tmp_path):
    ck = Checkpointer(tmp_path)
    ck.save(5, _tree())
    # simulate a torn write
    bad = tmp_path / "step_0000000009"
    bad.mkdir()
    (bad / "arrays.npz").write_bytes(b"garbage")
    assert ck.latest_step() == 5


def test_restore_mismatched_shape_fails(tmp_path):
    ck = Checkpointer(tmp_path)
    ck.save(1, {"a": jnp.zeros((4,))})
    with pytest.raises(AssertionError):
        ck.restore(1, {"a": jax.ShapeDtypeStruct((5,), jnp.float32)})


TRAIN = [sys.executable, "-m", "repro.launch.train", "--arch", "qwen2.5-3b",
         "--reduced", "--batch", "2", "--seq", "64"]


def _env():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    return env


@pytest.mark.slow
def test_kill_and_resume(tmp_path):
    """SIGTERM mid-run -> checkpoint + exit 143; restart resumes and the
    loss trajectory continues from the checkpointed step."""
    ckdir = str(tmp_path / "ck")
    p = subprocess.Popen(TRAIN + ["--steps", "60", "--ckpt-dir", ckdir,
                                  "--ckpt-every", "10"],
                         env=_env(), cwd=os.getcwd(),
                         stdout=subprocess.PIPE, text=True)
    # wait for some progress then preempt
    seen = ""
    t0 = time.time()
    while time.time() - t0 < 300:
        line = p.stdout.readline()
        seen += line
        if "step=20" in line:
            p.send_signal(signal.SIGTERM)
            break
    out, _ = p.communicate(timeout=300)
    assert p.returncode == 143, (p.returncode, seen + out)

    r = subprocess.run(TRAIN + ["--steps", "40", "--ckpt-dir", ckdir],
                       env=_env(), cwd=os.getcwd(),
                       capture_output=True, text=True, timeout=600)
    assert r.returncode == 0, r.stdout + r.stderr
    assert "resumed from step" in r.stdout
    assert "final loss" in r.stdout
