"""Minimal deterministic stand-in for `hypothesis` (gated dependency).

The container does not ship hypothesis and nothing may be pip-installed,
so ``conftest.py`` installs this shim into ``sys.modules`` **only when the
real package is missing** — with hypothesis available the genuine library
wins and this file is inert.

Covers exactly the strategy surface the suite uses (integers, sampled_from,
just, builds, tuples, lists, text, fixed_dictionaries, ``.map``) with a
seeded ``random.Random``: each ``@given`` test runs ``max_examples``
deterministic examples, so property tests stay reproducible across runs
instead of being skipped wholesale.  No shrinking, no database — failures
report the drawn arguments in the assertion traceback.
"""
from __future__ import annotations

import functools
import inspect
import random
import string
import sys
import types

_SEED = 0


class Strategy:
    def __init__(self, draw):
        self._draw = draw

    def example(self, rng: random.Random):
        return self._draw(rng)

    def map(self, fn):
        return Strategy(lambda rng: fn(self._draw(rng)))

    def filter(self, pred, _tries: int = 1000):
        def draw(rng):
            for _ in range(_tries):
                v = self._draw(rng)
                if pred(v):
                    return v
            raise ValueError("filter predicate never satisfied")
        return Strategy(draw)


def integers(min_value=-(2 ** 31), max_value=2 ** 31):
    return Strategy(lambda rng: rng.randint(min_value, max_value))


def floats(min_value=0.0, max_value=1.0, **_):
    return Strategy(lambda rng: rng.uniform(min_value, max_value))


def booleans():
    return Strategy(lambda rng: rng.random() < 0.5)


def just(value):
    return Strategy(lambda rng: value)


def sampled_from(elements):
    elements = list(elements)
    return Strategy(lambda rng: elements[rng.randrange(len(elements))])


def tuples(*strategies):
    return Strategy(lambda rng: tuple(s.example(rng) for s in strategies))


def lists(elements, min_size=0, max_size=10, **_):
    return Strategy(lambda rng: [
        elements.example(rng)
        for _ in range(rng.randint(min_size, max_size))])


def text(alphabet=string.ascii_letters, min_size=0, max_size=10):
    pool = list(alphabet)
    return Strategy(lambda rng: "".join(
        pool[rng.randrange(len(pool))]
        for _ in range(rng.randint(min_size, max_size))))


def fixed_dictionaries(mapping):
    return Strategy(lambda rng: {
        k: s.example(rng) for k, s in mapping.items()})


def builds(target, *args, **kwargs):
    return Strategy(lambda rng: target(
        *(a.example(rng) for a in args),
        **{k: v.example(rng) for k, v in kwargs.items()}))


def given(*strategies, **kw_strategies):
    def decorate(fn):
        @functools.wraps(fn)
        def wrapper(*fargs, **fkwargs):
            n = getattr(wrapper, "_shim_max_examples",
                        getattr(fn, "_shim_max_examples", 100))
            rng = random.Random(_SEED)
            for _ in range(n):
                drawn = [s.example(rng) for s in strategies]
                kdrawn = {k: s.example(rng)
                          for k, s in kw_strategies.items()}
                fn(*fargs, *drawn, **dict(fkwargs, **kdrawn))
        wrapper._shim_given = True
        # hide the drawn parameters from pytest's fixture resolution: the
        # remaining (leading) parameters, if any, are genuine fixtures
        params = list(inspect.signature(fn).parameters.values())
        keep = params[:max(0, len(params) - len(strategies))]
        keep = [p for p in keep if p.name not in kw_strategies]
        del wrapper.__wrapped__
        wrapper.__signature__ = inspect.Signature(keep)
        return wrapper
    return decorate


def settings(max_examples: int = 100, deadline=None, **_):
    def decorate(fn):
        fn._shim_max_examples = max_examples
        return fn
    return decorate


def install() -> None:
    """Register the shim as `hypothesis` / `hypothesis.strategies`."""
    mod = types.ModuleType("hypothesis")
    st_mod = types.ModuleType("hypothesis.strategies")
    for name in ("integers", "floats", "booleans", "just", "sampled_from",
                 "tuples", "lists", "text", "fixed_dictionaries", "builds"):
        setattr(st_mod, name, globals()[name])
    mod.given = given
    mod.settings = settings
    mod.strategies = st_mod
    mod.__shim__ = True
    sys.modules["hypothesis"] = mod
    sys.modules["hypothesis.strategies"] = st_mod
