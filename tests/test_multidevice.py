"""Multi-device behaviour (subprocess with XLA_FLAGS so the main test
process keeps its single real device): debug-mesh dry-run plumbing, sharded
train step numerics vs single device, compressed cross-pod gradients."""
import os
import subprocess
import sys

import pytest

ENV = dict(os.environ, PYTHONPATH="src",
           XLA_FLAGS="--xla_force_host_platform_device_count=8")


def _run(code: str) -> str:
    r = subprocess.run([sys.executable, "-c", code], env=ENV,
                       capture_output=True, text=True, timeout=900,
                       cwd=os.getcwd())
    assert r.returncode == 0, r.stdout + "\n" + r.stderr
    return r.stdout


@pytest.mark.slow
def test_debug_mesh_cells_compile():
    out = _run("""
import jax
from repro import configs
from repro.dist import partition
from repro.models.config import ShapeConfig
from repro.launch.dryrun import build_cell
mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
partition.set_mesh(mesh)
for arch in ("qwen3-moe-235b-a22b", "deepseek-v2-236b", "recurrentgemma-9b",
             "mamba2-2.7b", "hubert-xlarge"):
    cfg = configs.get_reduced(arch)
    kinds = [("train", 64, 4), ("prefill", 64, 2)]
    if not cfg.encoder_only:
        kinds.append(("decode", 64, 4))
    for kind, seq, b in kinds:
        shape = ShapeConfig(f"{kind}_t", kind, seq, b)
        fn, args, shardings, out_sh, donate = build_cell(cfg, shape, mesh)
        jax.jit(fn, in_shardings=shardings, out_shardings=out_sh,
                donate_argnums=donate).lower(*args).compile()
        print("OK", arch, kind)
print("ALL_COMPILED")
""")
    assert "ALL_COMPILED" in out


@pytest.mark.slow
def test_sharded_loss_matches_single_device():
    out = _run("""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro import configs
from repro.dist import partition
from repro.models import api
cfg = configs.get_reduced("qwen2.5-3b")
params = api.init_params(cfg, jax.random.key(0))
batch = api.make_batch(cfg, 4, 64)
loss1, _ = jax.jit(lambda p, b: api.loss_fn(p, cfg, b))(params, batch)

mesh = jax.make_mesh((2, 4), ("data", "model"))
partition.set_mesh(mesh)
named = lambda tree: jax.tree.map(lambda s: NamedSharding(mesh, s), tree,
                                  is_leaf=lambda x: isinstance(x, P))
ps = named(partition.param_specs(params, mesh))
bs = named(partition.batch_specs(batch, mesh))
params_s = jax.device_put(params, ps)
batch_s = jax.device_put(batch, bs)
loss2, _ = jax.jit(lambda p, b: api.loss_fn(p, cfg, b),
                   in_shardings=(ps, bs))(params_s, batch_s)
partition.set_mesh(None)
diff = abs(float(loss1) - float(loss2))
print("LOSS_DIFF", diff)
assert diff < 5e-3, diff
print("MATCHED")
""")
    assert "MATCHED" in out


@pytest.mark.slow
def test_compressed_cross_pod_gradients():
    out = _run("""
import jax, jax.numpy as jnp, numpy as np
from repro.dist.compression import cross_pod_mean, init_error_state
mesh = jax.make_mesh((2, 4), ("pod", "data"))
g = {"w": jax.random.normal(jax.random.key(0), (16, 64), jnp.float32)}
err = init_error_state(g)
mean, err2 = cross_pod_mean(g, err, mesh)
# exact mean over an axis where every shard holds identical values = itself
np.testing.assert_allclose(np.asarray(mean["w"]), np.asarray(g["w"]),
                           atol=np.max(np.abs(np.asarray(g["w"]))) / 100)
# error feedback: residual shrinks the *accumulated* quantization error
total = np.asarray(mean["w"]) + 0
for _ in range(3):
    mean, err2 = cross_pod_mean(g, err2, mesh)
print("COMPRESSION_OK")
""")
    assert "COMPRESSION_OK" in out


@pytest.mark.slow
def test_autotune_on_debug_mesh():
    """Beyond-paper: the scientist's loop over framework genomes, evaluated
    by compile-and-analyse on a small mesh."""
    out = _run("""
import jax
from repro.core.autotune import FrameworkGenome, autotune_cell
mesh = jax.make_mesh((2, 2), ("data", "model"))
res = autotune_cell("qwen2.5-3b", "train_4k", budget=3, mesh=mesh,
                    verbose=False)
assert res["best"]["status"] == "ok", res["best"]
assert res["submissions"] <= 3
assert len(res["log"]) >= 1
print("AUTOTUNE_OK", res["best"]["dominant"])
""")
    assert "AUTOTUNE_OK" in out
