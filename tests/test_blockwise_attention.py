"""The XLA-path flash attention (custom VJP) vs oracle: values + grads."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.models.common import blockwise_attention


def _qkv(rng, b, hq, hkv, s, d, dv=None):
    dv = dv or d
    q = jnp.asarray(rng.standard_normal((b, hq, s, d)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, hkv, s, d)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, hkv, s, dv)), jnp.float32)
    return q, k, v


@pytest.mark.parametrize("causal,window", [(True, None), (False, None),
                                           (True, 48)])
def test_forward_matches(rng, causal, window):
    q, k, v = _qkv(rng, 2, 4, 2, 128, 32)
    got = blockwise_attention(q, k, v, causal=causal, window=window,
                              q_chunk=32, k_chunk=32)
    want = ref.attention(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5)


@pytest.mark.parametrize("causal,window", [(True, None), (True, 48),
                                           (False, None)])
def test_gradients_match(rng, causal, window):
    q, k, v = _qkv(rng, 1, 2, 1, 64, 32)

    def f(fn):
        return lambda q, k, v: jnp.sum(
            jnp.sin(fn(q, k, v).astype(jnp.float32)))

    ours = jax.grad(f(lambda q, k, v: blockwise_attention(
        q, k, v, causal=causal, window=window, q_chunk=32, k_chunk=32)),
        argnums=(0, 1, 2))(q, k, v)
    theirs = jax.grad(f(lambda q, k, v: ref.attention(
        q, k, v, causal=causal, window=window)), argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(ours, theirs):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=3e-5)


def test_mla_style_dv_neq_dq(rng):
    q, k, v = _qkv(rng, 1, 4, 4, 64, 48, dv=32)
    got = blockwise_attention(q, k, v, causal=True, q_chunk=32, k_chunk=32)
    assert got.shape == (1, 4, 64, 32)
    want = _naive(q, k, v)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5)


def _naive(q, k, v):
    d = q.shape[-1]
    s = q.shape[2]
    logits = jnp.einsum("bhqd,bhkd->bhqk", q, k) / np.sqrt(d)
    mask = np.tril(np.ones((s, s), bool))
    logits = jnp.where(mask, logits, -1e30)
    return jnp.einsum("bhqk,bhkd->bhqd", jax.nn.softmax(logits, -1), v)


def test_kv_len_masking(rng):
    q, k, v = _qkv(rng, 2, 2, 2, 64, 32)
    kv_len = jnp.array([40, 64], jnp.int32)
    got = blockwise_attention(q, k, v, causal=True, kv_len=kv_len,
                              q_chunk=32, k_chunk=32)
    want_full = ref.attention(q, k, v, causal=True)
    # rows before kv_len see only valid keys == plain causal result there
    np.testing.assert_allclose(np.asarray(got[0, :, :40]),
                               np.asarray(want_full[0, :, :40]), atol=2e-5)
    np.testing.assert_allclose(np.asarray(got[1]),
                               np.asarray(want_full[1]), atol=2e-5)


def test_unroll_mode_identical(rng):
    """exact_count accounting mode must not change values."""
    q, k, v = _qkv(rng, 1, 2, 1, 128, 32)
    a = blockwise_attention(q, k, v, causal=True, q_chunk=32, k_chunk=32)
    b = blockwise_attention(q, k, v, causal=True, q_chunk=32, k_chunk=32,
                            unroll=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)
