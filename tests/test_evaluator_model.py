"""Analytic cost-model properties (hypothesis) + wall-clock backend."""
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import codegen
from repro.core.evaluator import EvaluationService, estimate_us
from repro.core.genome import SEED_MXU, KernelGenome

dims = st.sampled_from([512, 1024, 2048, 4096])
blocks = st.sampled_from([128, 256, 512])


@settings(max_examples=40, deadline=None)
@given(dims, dims, dims, blocks, blocks, blocks)
def test_monotone_in_problem_size(m, n, k, bm, bn, bk):
    g = KernelGenome(style="blocked", block_m=bm, block_n=bn, block_k=bk)
    t1 = estimate_us(g, m, n, k)
    t2 = estimate_us(g, 2 * m, n, k)
    assert t2 >= t1 > 0


@settings(max_examples=20, deadline=None)
@given(dims, dims, dims)
def test_f32_never_faster_than_bf16(m, n, k):
    g16 = KernelGenome(style="blocked", block_m=256, block_n=256, block_k=256)
    g32 = g16.replace(compute_dtype="float32")
    assert estimate_us(g32, m, n, k) >= estimate_us(g16, m, n, k)


@settings(max_examples=20, deadline=None)
@given(dims, dims, dims)
def test_split_k_is_never_free(m, n, k):
    """On a single sequential TPU core split-K only adds partial-sum
    traffic — the cost model must reflect that (the Designer believes
    otherwise; the loop's refutations depend on this asymmetry)."""
    g1 = KernelGenome(style="blocked", block_m=256, block_n=256, block_k=256)
    g2 = g1.replace(k_split=4)
    assert estimate_us(g2, m, n, k) >= estimate_us(g1, m, n, k)


def test_bigger_blocks_cut_hbm_traffic():
    small = KernelGenome(style="blocked", block_m=128, block_n=128,
                         block_k=128)
    big = KernelGenome(style="blocked", block_m=1024, block_n=512,
                       block_k=256)
    # memory-bound regime: thin K
    assert estimate_us(big, 6144, 7168, 512) < estimate_us(small, 6144,
                                                           7168, 512)


def test_wall_clock_backend_runs():
    svc = EvaluationService(backend="wall_clock",
                            bench_configs=((256, 256, 256),),
                            correctness_config=(256, 256, 256))
    res = svc.submit(codegen.render_source(SEED_MXU))
    assert res.status == "ok"
    (t,) = res.timings_us.values()
    assert t > 0
