"""Genome invariants (hypothesis) + generated-source correctness."""
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core import codegen
from repro.core.genome import SEED_LIBRARY, SEED_MXU, SEED_NAIVE, KernelGenome
from repro.kernels import ref

blocks = st.sampled_from([128, 256, 512, 1024])
genomes = st.builds(
    KernelGenome,
    style=st.just("blocked"),
    block_m=blocks, block_n=blocks, block_k=blocks,
    grid_order=st.sampled_from(["mn", "nm"]),
    scale_application=st.sampled_from(["scale_acc", "dequant_inputs"]),
    compute_dtype=st.sampled_from(["bfloat16", "float32"]),
    k_split=st.sampled_from([1, 2, 4]),
)


@settings(max_examples=30, deadline=None)
@given(genomes)
def test_json_roundtrip(g):
    assert KernelGenome.from_json(g.to_json()) == g


@settings(max_examples=30, deadline=None)
@given(genomes)
def test_valid_genomes_have_bounded_vmem(g):
    if not g.validate():
        assert g.vmem_bytes() <= 96 * 2**20


@settings(max_examples=10, deadline=None)
@given(genomes)
def test_generated_source_is_correct(g):
    """Every legal genome's rendered source computes the right answer."""
    if g.validate():
        return
    run, gj = codegen.load_kernel(codegen.render_source(g))
    assert KernelGenome.from_json(gj) == g
    rng = np.random.default_rng(0)
    m, k, n = 256, 256, 256
    a32 = jnp.asarray(rng.standard_normal((m, k)), jnp.float32)
    b32 = jnp.asarray(rng.standard_normal((k, n)), jnp.float32)
    aq, a_s = ref.quantize_blockwise(a32)
    bq, b_s = ref.quantize_blockwise_2d(b32)
    want = ref.scaled_gemm(aq, bq, a_s, b_s).astype(jnp.float32)
    got = np.asarray(run(aq, bq, a_s, b_s), dtype=np.float32)
    scale = float(jnp.max(jnp.abs(want)))
    np.testing.assert_allclose(got, np.asarray(want), atol=0.03 * scale)


def test_seed_sources_run():
    for g in (SEED_LIBRARY, SEED_NAIVE, SEED_MXU):
        run, _ = codegen.load_kernel(codegen.render_source(g))
        rng = np.random.default_rng(1)
        a32 = jnp.asarray(rng.standard_normal((128, 256)), jnp.float32)
        b32 = jnp.asarray(rng.standard_normal((256, 128)), jnp.float32)
        aq, a_s = ref.quantize_blockwise(a32)
        bq, b_s = ref.quantize_blockwise_2d(b32)
        want = ref.scaled_gemm(aq, bq, a_s, b_s).astype(jnp.float32)
        got = np.asarray(run(aq, bq, a_s, b_s), dtype=np.float32)
        scale = float(jnp.max(jnp.abs(want)))
        np.testing.assert_allclose(got, np.asarray(want), atol=0.03 * scale)


def test_invalid_vmem_rejected():
    g = KernelGenome(style="blocked", block_m=4096, block_n=4096,
                     block_k=4096)
    assert any("VMEM" in e for e in g.validate())


def test_unaligned_block_k_rejected():
    g = KernelGenome(style="blocked", block_k=192)
    assert any("block_k" in e for e in g.validate())
