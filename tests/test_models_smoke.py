"""Per-arch smoke tests: every assigned architecture's reduced config runs a
train step (finite loss, finite grads) and — where applicable — a
prefill+decode that agrees with the full forward pass."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import api
from repro.optim import adamw
from repro.train import make_train_step

ARCHS = list(configs.ARCH_IDS)


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_finite(arch):
    cfg = configs.get_reduced(arch)
    params = api.init_params(cfg, jax.random.key(0))
    opt = adamw.init(params)
    batch = api.make_batch(cfg, 2, 64)
    step = jax.jit(make_train_step(cfg, peak_lr=1e-3, total_steps=10))
    params, opt, metrics = step(params, opt, batch, jnp.int32(0))
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    for leaf in jax.tree.leaves(params):
        assert np.all(np.isfinite(np.asarray(leaf, dtype=np.float32)))


@pytest.mark.parametrize("arch", ARCHS)
def test_output_shapes(arch):
    cfg = configs.get_reduced(arch)
    params = api.init_params(cfg, jax.random.key(0))
    batch = api.make_batch(cfg, 2, 64)
    logits, cache = jax.jit(
        lambda p, b: api.prefill(p, cfg, b, 96))(params, batch)
    assert logits.shape == (2, cfg.vocab_padded)
    assert np.all(np.isfinite(np.asarray(logits)))
    if cfg.encoder_only:
        assert cache is None


@pytest.mark.parametrize("arch", [a for a in ARCHS
                                  if not configs.get_reduced(a).encoder_only
                                  and configs.get_reduced(a).inputs == "tokens"])
def test_prefill_decode_matches_forward(arch):
    """Greedy continuation via (prefill + decode_step) must match running
    the full sequence through the forward pass (f32 params for tightness)."""
    cfg = dataclasses.replace(configs.get_reduced(arch),
                              param_dtype="float32")
    if cfg.moe is not None:  # drops in prefill-but-not-decode break parity
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=100.0))
    params = api.init_params(cfg, jax.random.key(1))
    rng = np.random.default_rng(0)
    b, s = 2, 48
    toks = rng.integers(0, cfg.vocab, (b, s + 1)).astype(np.int32)

    # full forward logits at position s-1 predict token at s
    full = {"tokens": jnp.asarray(toks)}
    logits_full, _ = api.prefill(params, cfg, full, s + 1)  # last position

    # prefill on the first s tokens, then decode token s
    pre = {"tokens": jnp.asarray(toks[:, :s])}
    logits_pre, cache = api.prefill(params, cfg, pre, s + 8)
    logits_dec, cache = api.decode_step(
        params, cfg, cache, jnp.asarray(toks[:, s]))

    np.testing.assert_allclose(
        np.asarray(logits_dec), np.asarray(logits_full),
        atol=2e-3, rtol=2e-3)


def test_moe_capacity_dropless_at_decode():
    from repro.models.config import MoEConfig
    from repro.models.moe import _capacity
    cfg = MoEConfig(n_experts=8, top_k=2, d_ff_expert=16, router_groups=4)
    assert _capacity(2, cfg) == 4     # Tg*k: exact-dropless when tiny


def test_mrope_decode_runs():
    cfg = dataclasses.replace(configs.get_reduced("qwen2-vl-72b"),
                              param_dtype="float32")
    params = api.init_params(cfg, jax.random.key(0))
    batch = api.make_batch(cfg, 2, 32)
    _, cache = api.prefill(params, cfg, batch, 48)
    logits, cache = api.decode_step(params, cfg, cache,
                                    jnp.array([1, 2], jnp.int32))
    assert np.all(np.isfinite(np.asarray(logits)))


def test_encoder_has_no_decode():
    cfg = configs.get_reduced("hubert-xlarge")
    with pytest.raises(ValueError, match="encoder-only"):
        api.decode_step(None, cfg, None, None)


@pytest.mark.parametrize("arch", ["mamba2-2.7b", "recurrentgemma-9b"])
def test_subquadratic_long_decode_state_is_constant_size(arch):
    """long_500k viability: cache size must not grow with max_seq."""
    cfg = configs.get_reduced(arch)
    c1 = api.init_cache(cfg, 1, 1_024)
    c2 = api.init_cache(cfg, 1, 65_536)
    s1 = sum(x.size for k, x in c1.items() if k != "len")
    s2 = sum(x.size for k, x in c2.items() if k != "len")
    if cfg.family == "ssm":
        assert s1 == s2
    else:  # rglru: only the fixed window grows caches, already capped
        assert s2 <= s1 * (cfg.rglru.window / min(1024, cfg.rglru.window))
