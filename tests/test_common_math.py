"""Numerics of the shared layers: RoPE/M-RoPE, RMSNorm, chunked xent."""
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.models.common import (
    apply_mrope, apply_rope, chunked_softmax_xent, rms_norm,
)


def test_rope_preserves_norm(rng):
    x = jnp.asarray(rng.standard_normal((2, 4, 16, 32)), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(16, dtype=jnp.int32), (2, 16))
    y = apply_rope(x, pos, 1e4)
    np.testing.assert_allclose(
        np.linalg.norm(np.asarray(y), axis=-1),
        np.linalg.norm(np.asarray(x), axis=-1), rtol=1e-5)


def test_rope_relative_property(rng):
    """<rope(q,i), rope(k,j)> depends only on i - j."""
    q = jnp.asarray(rng.standard_normal((1, 1, 1, 32)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((1, 1, 1, 32)), jnp.float32)

    def dot_at(i, j):
        qi = apply_rope(q, jnp.array([[i]], jnp.int32), 1e4)
        kj = apply_rope(k, jnp.array([[j]], jnp.int32), 1e4)
        return float(jnp.sum(qi * kj))

    assert abs(dot_at(5, 3) - dot_at(12, 10)) < 1e-4
    assert abs(dot_at(5, 3) - dot_at(6, 3)) > 1e-6


def test_mrope_reduces_to_rope_for_equal_components(rng):
    x = jnp.asarray(rng.standard_normal((2, 2, 8, 24)), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(8, dtype=jnp.int32), (2, 8))
    pos3 = jnp.stack([pos, pos, pos], 0)
    np.testing.assert_allclose(np.asarray(apply_mrope(x, pos3, 1e4)),
                               np.asarray(apply_rope(x, pos, 1e4)),
                               atol=1e-5)


def test_rms_norm(rng):
    x = jnp.asarray(rng.standard_normal((4, 32)), jnp.float32) * 7
    y = rms_norm(x, jnp.zeros((32,)), 1e-6)
    rms = np.sqrt(np.mean(np.asarray(y) ** 2, -1))
    np.testing.assert_allclose(rms, 1.0, rtol=1e-3)


@settings(max_examples=20, deadline=None)
@given(st.integers(1, 4).map(lambda i: 2 ** i))
def test_chunked_xent_matches_direct(n_chunks):
    t, d, v = 32, 16, 64
    key = jax.random.key(0)
    x = jax.random.normal(key, (t, d), jnp.float32)
    emb = jax.random.normal(jax.random.key(1), (v, d), jnp.float32)
    labels = jax.random.randint(jax.random.key(2), (t,), 0, v, jnp.int32)
    nll, denom = chunked_softmax_xent(x, emb, labels,
                                      chunk=t // n_chunks)
    logits = x @ emb.T
    direct = -jax.nn.log_softmax(logits)[jnp.arange(t), labels].sum()
    np.testing.assert_allclose(float(nll), float(direct), rtol=1e-5)
    assert float(denom) == t


def test_chunked_xent_grads_match(rng):
    t, d, v = 16, 8, 32
    x = jnp.asarray(rng.standard_normal((t, d)), jnp.float32)
    emb = jnp.asarray(rng.standard_normal((v, d)), jnp.float32)
    labels = jnp.asarray(rng.integers(0, v, t), jnp.int32)

    def f_chunk(x, emb):
        nll, _ = chunked_softmax_xent(x, emb, labels, chunk=4)
        return nll

    def f_direct(x, emb):
        return -jax.nn.log_softmax(x @ emb.T)[jnp.arange(t), labels].sum()

    g1 = jax.grad(f_chunk, argnums=(0, 1))(x, emb)
    g2 = jax.grad(f_direct, argnums=(0, 1))(x, emb)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)
