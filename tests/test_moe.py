"""MoE sorted-dispatch correctness vs a naive per-token loop."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import MoEConfig
from repro.models.moe import init_moe_ffn, moe_ffn
from repro.models.common import KeyGen


def _naive_moe(params, x, cfg, norm_topk):
    logits = x.astype(jnp.float32) @ params["router"]
    probs = jax.nn.softmax(logits, axis=-1)
    gates, ids = jax.lax.top_k(probs, cfg.top_k)
    if norm_topk:
        gates = gates / jnp.sum(gates, axis=-1, keepdims=True)
    y = jnp.zeros_like(x, dtype=jnp.float32)
    for t in range(x.shape[0]):
        for j in range(cfg.top_k):
            e = int(ids[t, j])
            h = x[t] @ params["w_gate"][e]
            u = x[t] @ params["w_up"][e]
            o = (jax.nn.silu(h.astype(jnp.float32)).astype(u.dtype) * u
                 ) @ params["w_down"][e]
            y = y.at[t].add(gates[t, j] * o.astype(jnp.float32))
    if "ws_gate" in params:
        h = x @ params["ws_gate"]
        u = x @ params["ws_up"]
        y = y + ((jax.nn.silu(h.astype(jnp.float32)).astype(u.dtype) * u)
                 @ params["ws_down"]).astype(jnp.float32)
    return y


def test_dispatch_matches_naive_when_dropless():
    cfg = MoEConfig(n_experts=8, top_k=2, d_ff_expert=16, router_groups=2,
                    capacity_factor=100.0)   # no drops
    kg = KeyGen(jax.random.key(0))
    params = init_moe_ffn(kg, 32, cfg, dtype=jnp.float32)
    x = jax.random.normal(jax.random.key(1), (16, 32), jnp.float32)
    got, aux = moe_ffn(params, x, cfg, norm_topk=True)
    want = _naive_moe(params, x, cfg, norm_topk=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-5, rtol=1e-4)
    assert float(aux["moe_aux"]) >= 1.0 - 1e-6   # >= 1 by Cauchy-Schwarz


def test_shared_experts_added():
    cfg = MoEConfig(n_experts=4, top_k=1, d_ff_expert=8, n_shared=2,
                    router_groups=1, capacity_factor=100.0)
    kg = KeyGen(jax.random.key(0))
    params = init_moe_ffn(kg, 16, cfg, dtype=jnp.float32)
    x = jax.random.normal(jax.random.key(1), (8, 16), jnp.float32)
    got, _ = moe_ffn(params, x, cfg, norm_topk=False)
    want = _naive_moe(params, x, cfg, norm_topk=False)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-5, rtol=1e-4)


def test_capacity_drops_tokens_not_correctness():
    """With capacity_factor 1.0 some tokens drop; output stays finite and
    un-dropped tokens keep nonzero output."""
    cfg = MoEConfig(n_experts=4, top_k=2, d_ff_expert=8, router_groups=1,
                    capacity_factor=1.0)
    kg = KeyGen(jax.random.key(0))
    params = init_moe_ffn(kg, 16, cfg, dtype=jnp.float32)
    x = jax.random.normal(jax.random.key(2), (32, 16), jnp.float32)
    got, _ = moe_ffn(params, x, cfg)
    assert np.all(np.isfinite(np.asarray(got)))
