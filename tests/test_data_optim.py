"""Data-pipeline determinism + optimizer behaviour + schedules."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.data import DataConfig, SyntheticLM
from repro.optim import AdamWConfig, adamw, schedule


def test_batches_deterministic_by_step():
    cfg = DataConfig(vocab=100, seq_len=32, global_batch=4)
    a = SyntheticLM(cfg).at_step(17)
    b = SyntheticLM(cfg).at_step(17)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = SyntheticLM(cfg).at_step(18)
    assert not np.array_equal(a["tokens"], c["tokens"])


def test_shards_are_disjoint_and_restart_safe():
    cfg = DataConfig(vocab=1000, seq_len=16, global_batch=8)
    s0 = SyntheticLM(cfg, shard_index=0, shard_count=2)
    s1 = SyntheticLM(cfg, shard_index=1, shard_count=2)
    b0, b1 = s0.at_step(5), s1.at_step(5)
    assert b0["tokens"].shape == (4, 16)
    assert not np.array_equal(b0["tokens"], b1["tokens"])
    np.testing.assert_array_equal(b0["tokens"], s0.at_step(5)["tokens"])


def test_labels_are_shifted_tokens():
    cfg = DataConfig(vocab=50, seq_len=16, global_batch=2)
    b = SyntheticLM(cfg).at_step(0)
    # label[t] is the next token: with copy structure this holds often but
    # structurally: labels come from the same stream, one position ahead
    assert b["tokens"].shape == b["labels"].shape


def test_adamw_minimizes_quadratic():
    params = {"w": jnp.array([3.0, -2.0])}
    state = adamw.init(params)
    cfg = AdamWConfig(weight_decay=0.0, clip_norm=1e9)
    for _ in range(200):
        grads = {"w": 2 * params["w"]}
        params, state, _ = adamw.update(grads, state, params, 0.05, cfg)
    assert float(jnp.max(jnp.abs(params["w"]))) < 1e-2


def test_grad_clip_bounds_update():
    params = {"w": jnp.zeros((4,))}
    state = adamw.init(params)
    cfg = AdamWConfig(clip_norm=1.0, weight_decay=0.0)
    _, _, metrics = adamw.update({"w": jnp.full((4,), 1e6)}, state, params,
                                 1e-3, cfg)
    assert metrics["grad_norm"] > 1e5  # reported raw


def test_cosine_schedule_shape():
    lrs = [float(schedule.cosine_with_warmup(
        jnp.int32(s), peak_lr=1.0, warmup_steps=10, total_steps=100))
        for s in range(100)]
    assert lrs[0] < lrs[9] <= 1.0 + 1e-6
    assert abs(max(lrs) - 1.0) < 0.1
    assert lrs[-1] < 0.2


def test_bf16_params_stay_bf16():
    params = {"w": jnp.ones((8, 8), jnp.bfloat16)}
    state = adamw.init(params)
    new_params, _, _ = adamw.update({"w": jnp.ones((8, 8), jnp.bfloat16)},
                                    state, params, 1e-2)
    assert new_params["w"].dtype == jnp.bfloat16
    assert state["m"]["w"].dtype == jnp.float32
