"""Mamba-2 SSD Pallas kernel vs sequential-scan oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref


def _inputs(rng, b, s, h, p, n):
    x = jnp.asarray(rng.standard_normal((b, s, h, p)), jnp.float32)
    dt = jax.nn.softplus(jnp.asarray(rng.standard_normal((b, s, h)), jnp.float32))
    a = -jnp.exp(jnp.asarray(rng.standard_normal((h,)), jnp.float32) * 0.5)
    bm = jnp.asarray(rng.standard_normal((b, s, n)), jnp.float32) / np.sqrt(n)
    cm = jnp.asarray(rng.standard_normal((b, s, n)), jnp.float32) / np.sqrt(n)
    return x, dt, a, bm, cm


@pytest.mark.parametrize("b,s,h,p,n,chunk", [
    (1, 128, 2, 32, 32, 32), (2, 256, 4, 32, 64, 64), (1, 64, 2, 64, 16, 64),
])
def test_ssd_matches_sequential(rng, b, s, h, p, n, chunk):
    x, dt, a, bm, cm = _inputs(rng, b, s, h, p, n)
    want = ref.ssd(x, dt, a, bm, cm)
    got = ops.ssd(x, dt, a, bm, cm, chunk=chunk)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=5e-4, rtol=1e-3)


def test_ssd_with_skip(rng):
    x, dt, a, bm, cm = _inputs(rng, 1, 128, 2, 32, 32)
    d_skip = jnp.asarray(rng.standard_normal((2,)), jnp.float32)
    want = ref.ssd(x, dt, a, bm, cm, d_skip=d_skip)
    got = ops.ssd(x, dt, a, bm, cm, d_skip=d_skip, chunk=64)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=5e-4, rtol=1e-3)


def test_jnp_chunked_ssd_matches_oracle(rng):
    """The XLA-path chunked SSD used by the model matches the oracle too."""
    from repro.models.ssm import ssd_chunked
    x, dt, a, bm, cm = _inputs(rng, 2, 128, 2, 16, 16)
    want = ref.ssd(x, dt, a, bm, cm)
    got, _ = ssd_chunked(x, dt, a, bm, cm, chunk=32)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=5e-4, rtol=1e-3)
