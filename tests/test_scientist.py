"""The Kernel Scientist loop: stage schemas, the pick-3 rule, platform
feedback, sequential enforcement, persistence, and end-to-end discovery."""
import json

import pytest
from hypothesis import given, settings, strategies as st

from repro.core import codegen, designer, prompts
from repro.core.evaluator import EvaluationService
from repro.core.genome import SEED_MONOLITH
from repro.core.llm import ScriptedLLM
from repro.core.population import BENCH_CONFIGS_18, Population, geomean
from repro.core.scientist import KernelScientist


@pytest.fixture(scope="module")
def sci():
    s = KernelScientist(llm=ScriptedLLM(), service=EvaluationService())
    s.run(generations=3)
    return s


def test_seeds_match_paper(sci):
    recs = list(sci.population)[:3]
    assert [r.rid for r in recs] == ["00001", "00002", "00003"]
    lib, naive, mxu = recs
    assert lib.genome.style == "library"
    # paper §3: the direct translation is ~6x slower than the library path
    assert 3.0 < naive.score / lib.score < 10.0


def test_selector_schema(sci):
    sel = sci.logbook[0].selection
    assert set(sel) == {"basis_code", "basis_reference", "rationale"}
    assert sel["basis_code"] in {r.rid for r in sci.population}
    assert len(sel["rationale"]) > 40


def test_designer_emits_10_avenues_and_5_plans():
    s = KernelScientist(llm=ScriptedLLM(), service=EvaluationService())
    s.seed()
    from repro.core import selector as sel_mod
    sel = sel_mod.select(s.population, s.llm)
    plans = designer.design(s.population, sel.basis_code,
                            sel.basis_reference, s.llm)
    assert 1 <= len(plans) <= 5
    for p in plans:
        assert {"description", "rubric", "performance",
                "innovation"} <= set(p)
        lo, hi = p["performance"]
        assert lo <= hi


perf = st.tuples(st.integers(-30, 80), st.integers(-30, 90)).map(
    lambda t: [min(t), max(t)])
plan = st.fixed_dictionaries({
    "description": st.text(min_size=1, max_size=8),
    "performance": perf,
    "innovation": st.integers(0, 100),
})


@settings(max_examples=50, deadline=None)
@given(st.lists(plan, min_size=3, max_size=5))
def test_pick3_rule_properties(plans):
    chosen = designer.pick3(plans)
    assert len(chosen) == 3
    assert len({id(c) for c in chosen}) == 3          # without replacement
    assert chosen[0]["innovation"] == max(p["innovation"] for p in plans)
    rest = [p for p in plans if p is not chosen[0]]
    assert chosen[1]["performance"][1] == max(
        p["performance"][1] for p in rest)


def test_population_lineage_and_persistence(tmp_path, sci):
    pop = sci.population
    best = pop.best()
    if best.parents:
        assert best.parents[0] in pop.ancestors(best.rid)
    pop.save(tmp_path / "pop.json")
    loaded = Population.load(tmp_path / "pop.json")
    assert len(loaded) == len(pop)
    assert loaded.best().rid == best.rid
    assert loaded.best().timings_us == best.timings_us


def test_loop_improves_over_seeds(sci):
    seed_best = min(r.score for r in list(sci.population)[:3])
    assert sci.population.best().score <= seed_best
    traj = sci.trajectory()
    vals = [t for _, t in traj]
    assert vals == sorted(vals, reverse=True)         # monotone best-so-far


def test_platform_rejects_broken_source():
    svc = EvaluationService()
    res = svc.submit("this is not python !!")
    assert res.status == "compile_error"
    res = svc.submit("x = 1\n")   # no run()
    assert res.status == "compile_error"


def test_platform_rejects_vmem_oom_monolith():
    svc = EvaluationService()
    src = codegen.render_source(SEED_MONOLITH)
    res = svc.submit(src)
    assert res.status == "compile_error"
    assert "RESOURCE_EXHAUSTED" in res.error


def test_platform_rejects_wrong_answers():
    svc = EvaluationService()
    src = ('GENOME = None\n'
           'import jax.numpy as jnp\n'
           'def run(a, b, a_scale, b_scale, interpret=True):\n'
           '    return jnp.zeros((a.shape[0], b.shape[1]), jnp.bfloat16)\n')
    res = svc.submit(src)
    assert res.status == "incorrect"


def test_sequential_submission_enforced():
    svc = EvaluationService()
    svc._lock.acquire()
    try:
        with pytest.raises(RuntimeError, match="sequential"):
            svc.submit("x = 1")
    finally:
        svc._lock.release()


def test_noise_is_deterministic():
    a = EvaluationService(noise=0.02, seed=7)
    b = EvaluationService(noise=0.02, seed=7)
    src = codegen.render_source(
        __import__("repro.core.genome", fromlist=["SEED_MXU"]).SEED_MXU)
    ra, rb = a.submit(src), b.submit(src)
    assert ra.timings_us == rb.timings_us
    c = EvaluationService(noise=0.02, seed=8)
    assert c.submit(src).timings_us != ra.timings_us


def test_geomean():
    assert geomean([1.0, 100.0]) == pytest.approx(10.0)
    assert geomean([]) == float("inf")
