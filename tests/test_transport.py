"""Distributed eval workers behind the unified ``EvalBackend`` API.

The acceptance scenarios of the transport layer:
  * wire protocol — length-prefixed JSONL frames round-trip; EOF is a clean
    ``None``; torn/corrupt frames raise instead of desyncing the stream;
  * worker death — a job whose worker dies is requeued at its original
    priority and re-evaluates to the identical verdict (content-keyed
    jitter); ``max_requeues`` bounds pathological crash loops;
  * subprocess transport — real ``eval_worker`` children speak the
    protocol, injected ``os._exit`` deaths respawn with stepped
    incarnations, job deadlines catch wedged evaluations;
  * pause/resume — a paused pool starts no new jobs but keeps queueing,
    and ``close()`` drains everything queued;
  * cache eviction — ``max_entries`` caps the LRU and compaction keeps
    ``eval_cache.jsonl`` O(max_entries);
  * the ``backend=`` constructor surface and its deprecated-kwarg shims;
  * @slow soak — a subprocess campaign with >= 20% injected worker-death
    rate finishes population-identical to an uninterrupted in-process
    ``workers=1`` run (the cross-transport determinism contract).
"""
import io
import json
import os
import pathlib
import threading
import time
import warnings

import pytest

import repro.core as core
from repro.core import codegen
from repro.core.evalpool import (
    PRIORITY_CAMPAIGN, EvalBackend, EvalCache, EvalPool,
)
from repro.core.eval_worker import EchoService, SleepyService, build_service
from repro.core.evaluator import EvalResult, EvaluationService
from repro.core.events import EventLog
from repro.core.genome import SEED_MXU
from repro.core.llm import ScriptedLLM
from repro.core.resilience import NO_WAIT_POLICY, CrashService, FlakyService
from repro.core.scientist import KernelScientist
from repro.core.transport import (
    InProcessTransport, RemoteEvalError, SubprocessTransport,
    WorkerDiedError, WorkerTransport, make_transport, read_frame,
    service_spec_of, write_frame,
)

SRC_OK = codegen.render_source(SEED_MXU, "transport test kernel")

#: Subprocess options tuned for tests: fast heartbeats, a deadline generous
#: enough for a cold child (jax import) but short enough to fail fast.
FAST_SUB = dict(heartbeat_interval_s=0.1, deadline_s=30.0,
                poll_interval_s=0.02)


# ---------------------------------------------------------------------------
# Wire protocol
# ---------------------------------------------------------------------------
def test_frame_round_trip():
    buf = io.BytesIO()
    frames = [{"frame": "submit", "job_id": 1, "source": "x = 1\n"},
              {"frame": "result", "timings_us": {"m1_n1_k1": 2.5},
               "note": "unicode µs → ok"},
              {"frame": "heartbeat"},
              {"frame": "submit", "source": "L" * 100_000}]  # large payload
    for f in frames:
        write_frame(buf, f)
    buf.seek(0)
    assert [read_frame(buf) for _ in frames] == frames
    assert read_frame(buf) is None           # clean EOF after the last frame


def test_frame_torn_and_corrupt_inputs():
    assert read_frame(io.BytesIO(b"")) is None
    with pytest.raises(ValueError, match="corrupt frame length"):
        read_frame(io.BytesIO(b"not-a-number\n{}\n"))
    whole = io.BytesIO()
    write_frame(whole, {"frame": "hello"})
    torn = io.BytesIO(whole.getvalue()[:-5])  # truncated payload
    with pytest.raises(ValueError, match="truncated"):
        read_frame(torn)
    with pytest.raises(ValueError, match="payload"):
        read_frame(io.BytesIO(b"8\n{\"frame\"\n"))  # right length, bad JSON


def test_service_spec_round_trip_rebuilds_equivalent_stack():
    svc = FlakyService(EvaluationService(noise=0.05, seed=9, latency_s=0.0),
                       seed=4, error_rate=0.2)
    spec = service_spec_of(svc)
    rebuilt = build_service(json.loads(json.dumps(spec)))  # via the wire
    assert type(rebuilt).__name__ == "FlakyService"
    assert (rebuilt.seed, rebuilt.error_rate) == (4, 0.2)
    assert rebuilt.inner.seed == 9 and rebuilt.inner.noise == 0.05
    # content-pure: the rebuilt stack times sources identically
    assert rebuilt.inner.submit(SRC_OK).timings_us == \
        svc.inner.submit(SRC_OK).timings_us
    with pytest.raises(TypeError, match="service_spec"):
        service_spec_of(object())


# ---------------------------------------------------------------------------
# EvalBackend protocol + the public surface
# ---------------------------------------------------------------------------
def test_evalpool_satisfies_evalbackend_protocol():
    pool = EvalPool([EvaluationService()], retry_policy=NO_WAIT_POLICY)
    assert isinstance(pool, EvalBackend)
    pool.close()

    class Incomplete:                        # no probe/state_dict/...
        def submit_async(self, source, priority=0, tag=None):
            pass

    assert not isinstance(Incomplete(), EvalBackend)


def test_core_all_exports_exactly_the_public_surface():
    assert len(core.__all__) == len(set(core.__all__))
    for name in core.__all__:
        assert not name.startswith("_"), f"{name} is private"
        assert getattr(core, name, None) is not None, f"{name} missing"
    ns = {}
    exec("from repro.core import *", ns)     # star import honours __all__
    assert set(core.__all__) <= set(ns)
    assert not {k for k in ns if k.startswith("_") and k != "__builtins__"}


# ---------------------------------------------------------------------------
# Worker death -> requeue (transport-agnostic, via a scripted transport)
# ---------------------------------------------------------------------------
class _DyingTransport(WorkerTransport):
    """Raises WorkerDiedError for the first ``deaths`` runs of each source,
    then answers with a content-keyed verdict — the subprocess failure mode
    without the subprocess."""

    kind = "scripted"

    def __init__(self, deaths=1, workers=1):
        self.deaths = deaths
        self.attempts = {}
        self.runs = 0
        self._workers = workers

    @property
    def num_workers(self):
        return self._workers

    def run(self, idx, source):
        self.runs += 1
        n = self.attempts[source] = self.attempts.get(source, 0) + 1
        if n <= self.deaths:
            self._emit("worker_died", worker=idx, incarnation=n - 1,
                       reason="scripted death", transport=self.kind)
            raise WorkerDiedError(f"scripted death #{n}")
        return EvalResult("ok", timings_us={"len": float(len(source))})

    def worker_states(self):
        return [None] * self._workers

    def load_worker_states(self, states):
        pass

    @property
    def submissions(self):
        return self.runs


def test_worker_death_requeues_job_to_identical_verdict():
    events = EventLog()
    transport = _DyingTransport(deaths=2)
    pool = EvalPool(transport=transport, events=events,
                    retry_policy=NO_WAIT_POLICY)
    handle = pool.submit_async("some kernel", tag="00042")
    res = handle.result(timeout=30)
    assert res.status == "ok"
    assert res.timings_us == {"len": float(len("some kernel"))}
    assert handle.requeues == 2              # died twice, landed the third
    requeues = events.select("worker_requeue")
    assert [r["tag"] for r in requeues] == ["00042", "00042"]
    assert [r["requeues"] for r in requeues] == [1, 2]
    assert len(events.select("worker_died")) == 2
    assert events.worker_lifecycle(worker=0)  # the lifecycle query sees both
    pool.close()


def test_requeue_keeps_original_priority():
    """A probe requeued after a death must not jump ahead of campaign work."""
    order = []

    class _Tracking(_DyingTransport):
        def run(self, idx, source):
            res = super().run(idx, source)
            order.append(source)
            return res

    gate = threading.Event()
    transport = _Tracking(deaths=0)
    real_run = transport.run

    def gated_run(idx, source):
        if source == "BLOCK":
            gate.wait(timeout=30)
            order.append(source)
            return EvalResult("ok", timings_us={})
        return real_run(idx, source)

    transport.run = gated_run
    pool = EvalPool(transport=transport, retry_policy=NO_WAIT_POLICY)
    blocker = pool.submit_async("BLOCK")
    time.sleep(0.05)                         # worker occupied on BLOCK
    probe = pool.probe("PROBE")
    campaign = pool.submit_async("CAMPAIGN")
    urgent = pool.urgent("URGENT")
    gate.set()
    for h in (blocker, probe, campaign, urgent):
        h.result(timeout=30)
    assert order == ["BLOCK", "URGENT", "CAMPAIGN", "PROBE"]
    pool.close()


def test_max_requeues_bounds_crash_loops():
    # requeue exhaustion is a terminal *verdict*, not an exception: the
    # campaign logs the doomed kernel (score inf) and keeps draining
    events = EventLog()
    pool = EvalPool(transport=_DyingTransport(deaths=10 ** 6), events=events,
                    retry_policy=NO_WAIT_POLICY, max_requeues=3)
    handle = pool.submit_async("doomed")
    res = handle.result(timeout=30)
    assert res.status == "worker_error"
    assert "gave up after 4 worker deaths" in res.error
    assert handle.requeues == 4              # 1 initial + 3 requeues
    assert len(events.select("worker_requeue")) == 4
    pool.close()


# ---------------------------------------------------------------------------
# Pause / resume
# ---------------------------------------------------------------------------
def test_pause_blocks_new_jobs_and_resume_drains():
    events = EventLog()
    svc = EchoService()
    pool = EvalPool([svc], events=events, retry_policy=NO_WAIT_POLICY,
                    idle_timeout_s=0.05)
    pool.pause()
    assert pool.paused and pool.stats()["paused"]
    handles = [pool.submit_async(f"k{i}") for i in range(3)]
    time.sleep(0.3)
    assert not any(h.done() for h in handles)  # nothing started while paused
    assert svc.submissions == 0
    pool.resume()
    assert not pool.paused
    for h in handles:
        assert h.result(timeout=30).status == "ok"
    assert [e["event"] for e in events.worker_lifecycle()] == \
        ["pool_pause", "pool_resume"]
    pool.close()


def test_close_unpauses_so_queued_work_drains():
    pool = EvalPool([EchoService()], retry_policy=NO_WAIT_POLICY,
                    idle_timeout_s=0.05)
    pool.pause()
    handle = pool.submit_async("queued while paused")
    pool.close(wait=True)                    # must not strand the job
    assert handle.result(timeout=30).status == "ok"


def test_pause_lets_inflight_job_finish():
    svc = EchoService(latency_s=0.3)
    pool = EvalPool([svc], retry_policy=NO_WAIT_POLICY, idle_timeout_s=0.05)
    first = pool.submit_async("inflight")
    time.sleep(0.1)                          # worker is mid-evaluation
    pool.pause()
    second = pool.submit_async("held")
    assert first.result(timeout=30).status == "ok"   # in-flight completes
    time.sleep(0.3)
    assert not second.done()                 # but nothing new starts
    pool.resume()
    assert second.result(timeout=30).status == "ok"
    pool.close()


# ---------------------------------------------------------------------------
# EvalCache LRU eviction + compaction
# ---------------------------------------------------------------------------
def _res(tag):
    return EvalResult("ok", timings_us={"t": float(tag)})


def test_cache_eviction_respects_max_entries():
    cache = EvalCache(max_entries=2)
    cache.put("k1", _res(1))
    cache.put("k2", _res(2))
    cache.get("k1")                          # refresh k1: k2 is now LRU
    cache.put("k3", _res(3))
    assert len(cache) == 2 and cache.evictions == 1
    assert cache.get("k2") is None           # the LRU entry was evicted
    assert cache.get("k1").timings_us == {"t": 1.0}
    assert cache.get("k3").timings_us == {"t": 3.0}
    stats = cache.stats()
    assert stats["max_entries"] == 2 and stats["evictions"] == 1
    with pytest.raises(ValueError, match="max_entries"):
        EvalCache(max_entries=0)


def test_cache_compaction_keeps_file_bounded(tmp_path):
    path = tmp_path / "cache.jsonl"
    cache = EvalCache(path, max_entries=2)
    for i in range(8):
        cache.put(f"k{i}", _res(i))
    assert len(cache) == 2 and cache.compactions >= 1
    lines = [l for l in path.read_text().splitlines() if l.strip()]
    assert len(lines) <= 2 + 2               # O(max_entries), not O(puts)
    cache.compact()
    lines = [l for l in path.read_text().splitlines() if l.strip()]
    assert len(lines) == 2                   # exactly the live entries
    # reload reconstructs the survivors (most recent two, recency order)
    reloaded = EvalCache(path, max_entries=2)
    assert reloaded.get("k6").timings_us == {"t": 6.0}
    assert reloaded.get("k7").timings_us == {"t": 7.0}
    assert reloaded.get("k0") is None


def test_cache_reload_trims_overfull_file_to_cap(tmp_path):
    path = tmp_path / "cache.jsonl"
    unbounded = EvalCache(path)              # grown without a cap...
    for i in range(5):
        unbounded.put(f"k{i}", _res(i))
    capped = EvalCache(path, max_entries=3)  # ...then reopened with one
    assert len(capped) == 3
    assert capped.get("k4") is not None and capped.get("k0") is None


# ---------------------------------------------------------------------------
# SubprocessTransport against real eval_worker children
# ---------------------------------------------------------------------------
def test_subprocess_round_trip_matches_inprocess_verdicts():
    events = EventLog()
    pool = EvalPool.of(EchoService(), workers=2, events=events,
                       retry_policy=NO_WAIT_POLICY, transport="subprocess",
                       transport_options=FAST_SUB)
    sources = [f"kernel variant {i}\n" for i in range(4)]
    handles = [pool.submit_async(s, tag=str(i))
               for i, s in enumerate(sources)]
    results = [h.result(timeout=60) for h in handles]
    local = EchoService()
    for src, res in zip(sources, results):
        assert res.status == "ok"
        assert res.timings_us == local.submit(src).timings_us
    assert pool.stats()["transport"] == "subprocess"
    assert pool.submissions == len(sources)
    spawns = events.select("worker_spawn")
    assert spawns and all(s["transport"] == "subprocess" for s in spawns)
    pool.close()
    # close() shut the children down cleanly
    assert len(events.select("worker_exit")) == len(spawns)


def test_subprocess_worker_kill_requeues_and_respawns():
    """CrashService(seed=0) inside the child os._exit()s deterministically;
    the parent must detect each death, respawn with a stepped incarnation,
    and requeue to the same content-keyed verdicts."""
    events = EventLog()
    svc = CrashService(EchoService(), seed=0, crash_rate=0.25)
    pool = EvalPool.of(svc, workers=1, events=events,
                       retry_policy=NO_WAIT_POLICY, transport="subprocess",
                       transport_options=FAST_SUB)
    sources = [f"crashy kernel {i}\n" for i in range(6)]
    handles = [pool.submit_async(s) for s in sources]
    results = [h.result(timeout=120) for h in handles]
    local = EchoService()
    for src, res in zip(sources, results):
        assert res.status == "ok"
        assert res.timings_us == local.submit(src).timings_us
    deaths = events.select("worker_died")
    assert deaths, "crash_rate=0.25 at seed 0 must kill at least one worker"
    assert len(events.select("worker_requeue")) == len(deaths)
    assert sum(h.requeues for h in handles) == len(deaths)
    # every respawn stepped the incarnation: 0, 1, 2, ...
    incs = [s["incarnation"] for s in events.select("worker_spawn")]
    assert incs == list(range(len(deaths) + 1))
    pool.close()


def test_subprocess_job_deadline_reaps_wedged_worker():
    events = EventLog()
    svc = SleepyService(EchoService(), match="STALL", sleep_s=60.0)
    opts = dict(FAST_SUB, job_timeout_s=2.0)
    pool = EvalPool.of(svc, workers=1, events=events,
                       retry_policy=NO_WAIT_POLICY, transport="subprocess",
                       transport_options=opts)
    handle = pool.submit_async("kernel with STALL marker\n")
    res = handle.result(timeout=120)         # incarnation 1 does not sleep
    assert res.status == "ok" and handle.requeues == 1
    [death] = events.select("worker_died")
    assert "job deadline" in death["reason"]
    pool.close()


def test_subprocess_remote_retry_exhaustion_is_not_a_death(tmp_path):
    """A child whose own retries are exhausted reports an error frame —
    the pool marks the submission failed instead of requeueing forever."""
    transport = SubprocessTransport(
        [{"kind": "flaky", "error_rate": 1.0, "seed": 0,
          "inner": {"kind": "echo"}}],
        policy=NO_WAIT_POLICY, **FAST_SUB)
    try:
        with pytest.raises(RemoteEvalError, match="TransientError"):
            transport.run(0, "always fails\n")
    finally:
        transport.close()


def test_make_transport_resolution():
    svc = EchoService()
    assert isinstance(make_transport("inprocess", [svc]), InProcessTransport)
    inst = InProcessTransport([svc])
    assert make_transport(inst, []) is inst
    sub = make_transport("subprocess", [svc])
    assert isinstance(sub, SubprocessTransport)
    sub.close()
    with pytest.raises(ValueError, match="unknown transport"):
        make_transport("carrier-pigeon", [svc])


# ---------------------------------------------------------------------------
# The backend= constructor surface + deprecated shims
# ---------------------------------------------------------------------------
def test_backend_accepts_a_constructed_pool_as_is():
    pool = EvalPool.of(EvaluationService(seed=2), workers=2,
                       cache=EvalCache(), retry_policy=NO_WAIT_POLICY)
    with warnings.catch_warnings():
        warnings.simplefilter("error")       # the new surface must not warn
        sci = KernelScientist(llm=ScriptedLLM(seed=2), backend=pool,
                              retry_policy=NO_WAIT_POLICY)
    assert sci.pool is pool
    assert pool.events is sci.events         # events attached on adoption
    pool.close()


def test_backend_wraps_a_bare_service_in_a_cached_pool(tmp_path):
    sci = KernelScientist(llm=ScriptedLLM(seed=2),
                          backend=EvaluationService(seed=2),
                          workdir=tmp_path / "wd",
                          retry_policy=NO_WAIT_POLICY)
    assert isinstance(sci.pool, EvalPool)
    assert sci.pool.cache is not None
    assert sci.pool.cache.path == tmp_path / "wd" / "eval_cache.jsonl"
    sci.pool.close()


def test_legacy_kwargs_still_work_with_deprecation_warning():
    with pytest.warns(DeprecationWarning, match="backend="):
        sci = KernelScientist(llm=ScriptedLLM(seed=5),
                              service=EvaluationService(seed=5, noise=0.05),
                              workers=3, retry_policy=NO_WAIT_POLICY)
    assert sci.pool.stats()["workers"] == 3
    sci.pool.close()

    with pytest.warns(DeprecationWarning):
        plain = KernelScientist(llm=ScriptedLLM(seed=5),
                                eval_cache=False,
                                retry_policy=NO_WAIT_POLICY)
    assert plain.pool.cache is None
    plain.pool.close()


def test_backend_and_legacy_kwargs_are_mutually_exclusive():
    with pytest.raises(TypeError, match="not both"):
        KernelScientist(backend=EvaluationService(),
                        service=EvaluationService())


def test_legacy_and_new_surface_produce_identical_campaigns():
    def snap(sci):
        return [(r.rid, r.parents, r.status, r.timings_us)
                for r in sci.population]

    with pytest.warns(DeprecationWarning):
        old = KernelScientist(llm=ScriptedLLM(seed=5),
                              service=EvaluationService(seed=5, noise=0.05),
                              retry_policy=NO_WAIT_POLICY)
    old.run(2)
    new = KernelScientist(
        llm=ScriptedLLM(seed=5),
        backend=EvalPool.of(EvaluationService(seed=5, noise=0.05),
                            cache=EvalCache(),
                            retry_policy=NO_WAIT_POLICY),
        retry_policy=NO_WAIT_POLICY)
    new.run(2)
    assert snap(new) == snap(old)
    old.pool.close()
    new.pool.close()


def test_service_setter_preserves_custom_cache_instance():
    """Regression: assigning .service used to rebuild the pool with a fresh
    default cache, silently dropping a custom EvalCache (and its path)."""
    custom = EvalCache(max_entries=50)
    sci = KernelScientist(
        llm=ScriptedLLM(seed=3),
        backend=EvalPool.of(EvaluationService(seed=3), cache=custom,
                            retry_policy=NO_WAIT_POLICY),
        retry_policy=NO_WAIT_POLICY)
    sci.service = EvaluationService(seed=4)
    assert sci.pool.cache is custom          # the very same instance
    assert sci.service.seed == 4
    sci.pool.close()


# ---------------------------------------------------------------------------
# @slow soak: the cross-transport determinism acceptance scenario
# ---------------------------------------------------------------------------
def _norm_population(workdir):
    d = json.loads((pathlib.Path(workdir) / "population.json").read_text())
    return json.dumps(d, sort_keys=True)


@pytest.mark.slow
def test_soak_subprocess_kills_match_inprocess_population(tmp_path):
    """A subprocess campaign with >= 20% injected worker-death rate must
    finish with the same final population (normalized population.json) as
    an uninterrupted in-process workers=1 run on the same seed."""
    soak_dir = pathlib.Path(os.environ.get("TRANSPORT_SOAK_DIR", tmp_path))
    soak_dir.mkdir(parents=True, exist_ok=True)
    seed, gens = 5, 6

    ref = KernelScientist(
        llm=ScriptedLLM(seed=seed),
        backend=EvalPool.of(EvaluationService(seed=seed, noise=0.05),
                            workers=1, cache=EvalCache(),
                            retry_policy=NO_WAIT_POLICY),
        workdir=soak_dir / "inprocess", retry_policy=NO_WAIT_POLICY)
    ref.run(gens)
    ref.pool.close()

    crashy = CrashService(EvaluationService(seed=seed, noise=0.05),
                          seed=0, crash_rate=0.25)   # >= 20% death rate
    sub = KernelScientist(
        llm=ScriptedLLM(seed=seed),
        backend=EvalPool.of(crashy, workers=2, cache=EvalCache(),
                            retry_policy=NO_WAIT_POLICY,
                            transport="subprocess",
                            transport_options=FAST_SUB),
        workdir=soak_dir / "subprocess", retry_policy=NO_WAIT_POLICY)
    sub.run(gens)
    stats = sub.pool.stats()
    counts = sub.events.counts()
    sub.pool.close()

    assert len(sub.logbook) == gens          # zero aborted generations
    assert counts.get("worker_died", 0) > 0, \
        "the soak must actually exercise worker deaths"
    assert counts.get("worker_requeue", 0) >= counts["worker_died"] > 0
    assert stats["transport"] == "subprocess"
    assert _norm_population(soak_dir / "subprocess") == \
        _norm_population(soak_dir / "inprocess")
