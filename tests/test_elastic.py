"""Elastic coordinator: failure exclusion, straggler detection, re-mesh."""
from repro.launch.elastic import ElasticCoordinator


class FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t


def test_dead_host_triggers_remesh():
    clk = FakeClock()
    co = ElasticCoordinator(n_hosts=128, chips_per_host=4, dead_after=60,
                            clock=clk)
    plan0 = co.plan_mesh()
    assert plan0["chips_used"] == 512
    clk.t = 50
    for h in list(co.hosts)[1:]:
        co.heartbeat(h, step=10, step_latency=1.0)
    clk.t = 100                      # only host0's last beat exceeds dead_after
    assert co.dead_hosts() == ["host0000"]
    plan = co.handle_failures()
    assert plan is not None
    assert plan["chips_used"] <= 127 * 4
    assert plan["mesh_shape"][1] == 16           # model axis preserved
    assert co.handle_failures() is None          # idempotent


def test_straggler_detection():
    clk = FakeClock()
    co = ElasticCoordinator(n_hosts=16, dead_after=1e9, clock=clk)
    for i, h in enumerate(co.hosts):
        co.heartbeat(h, step=5, step_latency=5.0 if i == 3 else 1.0)
    assert co.stragglers() == ["host0003"]
    plan = co.handle_failures()
    assert co.hosts["host0003"].excluded
    # 15 hosts * 4 chips = 60 -> model 16 x data 3 -> pow2 data 2 -> 32 chips
    assert plan["chips_used"] == 32


def test_shrink_below_model_axis():
    clk = FakeClock()
    co = ElasticCoordinator(n_hosts=3, chips_per_host=4, clock=clk)
    plan = co.plan_mesh()
    assert plan["mesh_shape"] == (1, 12) or plan["mesh_shape"][0] >= 1
