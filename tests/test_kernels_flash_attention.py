"""Pallas flash-attention kernels vs oracle: shape/dtype/GQA/window sweeps."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref


def _qkv(rng, b, hq, hkv, s, d, dtype):
    q = jnp.asarray(rng.standard_normal((b, hq, s, d)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, hkv, s, d)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, hkv, s, d)), jnp.float32)
    return q.astype(dtype), k.astype(dtype), v.astype(dtype)


@pytest.mark.parametrize("b,hq,hkv,s,d", [
    (1, 2, 1, 128, 64), (2, 4, 2, 256, 64), (1, 4, 4, 128, 128),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_prefill_causal(rng, b, hq, hkv, s, d, dtype):
    q, k, v = _qkv(rng, b, hq, hkv, s, d, dtype)
    want = ref.attention(q, k, v, causal=True).astype(jnp.float32)
    got = ops.attention(q, k, v, causal=True, block_q=64,
                        block_k=64).astype(jnp.float32)
    atol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=atol)


def test_prefill_local_window(rng):
    q, k, v = _qkv(rng, 2, 2, 1, 256, 64, jnp.float32)
    want = ref.attention(q, k, v, causal=True, window=64)
    got = ops.attention(q, k, v, causal=True, window=64, block_q=64,
                        block_k=64)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_decode_vs_ref(rng, dtype):
    b, hq, hkv, s, d = 3, 4, 2, 384, 64
    q = jnp.asarray(rng.standard_normal((b, hq, d)), jnp.float32).astype(dtype)
    k = jnp.asarray(rng.standard_normal((b, hkv, s, d)), jnp.float32).astype(dtype)
    v = jnp.asarray(rng.standard_normal((b, hkv, s, d)), jnp.float32).astype(dtype)
    kv_len = jnp.array([100, 384, 7], jnp.int32)
    want = ref.decode_attention(q, k, v, kv_len).astype(jnp.float32)
    got = ops.decode_attention(q, k, v, kv_len, block_k=128).astype(jnp.float32)
    atol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=atol)
