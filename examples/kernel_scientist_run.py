"""Full Kernel Scientist run with persisted artifacts: population JSON,
generation logbook, JSONL event log, and every generated kernel source.

    PYTHONPATH=src python examples/kernel_scientist_run.py --generations 20

The campaign checkpoints after every submission, so an interrupted run
(crash, Ctrl-C, preemption) continues where it left off:

    PYTHONPATH=src python examples/kernel_scientist_run.py --resume

``--fault-rate 0.2`` wraps the backends in the seeded fault injectors to
rehearse the paper's flaky-shared-queue regime (§3.4) end to end.
"""
import argparse
import pathlib

from repro.core import (EvaluationService, FlakyLLM, FlakyService,
                        KernelScientist, NO_WAIT_POLICY, ScriptedLLM)

ap = argparse.ArgumentParser()
ap.add_argument("--generations", type=int, default=20)
ap.add_argument("--workdir", default="results/scientist_run")
ap.add_argument("--noise", type=float, default=0.0,
                help="benchmark jitter sigma (platform realism)")
ap.add_argument("--seed", type=int, default=0)
ap.add_argument("--resume", action="store_true",
                help="continue the campaign persisted in --workdir")
ap.add_argument("--fault-rate", type=float, default=0.0,
                help="injected transient-failure rate for LLM + eval queue")
args = ap.parse_args()

llm = ScriptedLLM(seed=args.seed)
service = EvaluationService(noise=args.noise, seed=args.seed)
if args.fault_rate:
    llm = FlakyLLM(llm, seed=args.seed, error_rate=args.fault_rate / 2,
                   malformed_rate=args.fault_rate / 2)
    service = FlakyService(service, seed=args.seed,
                           error_rate=args.fault_rate)

if args.resume:
    sci = KernelScientist.resume(args.workdir, llm=llm, service=service,
                                 retry_policy=NO_WAIT_POLICY)
    print(f"resumed: {len(sci.logbook)} generations, "
          f"{len(sci.population)} kernels already on disk")
    # --generations is the campaign total; run() counts *additional*
    # generations (a resumed in-flight generation counts as one of them)
    todo = max(0, args.generations - len(sci.logbook))
else:
    sci = KernelScientist(llm=llm, service=service, workdir=args.workdir,
                          retry_policy=NO_WAIT_POLICY)
    todo = args.generations
best = sci.run(generations=todo)

wd = pathlib.Path(args.workdir)
(wd / "kernels").mkdir(exist_ok=True)
for rec in sci.population:
    (wd / "kernels" / f"{rec.rid}.py").write_text(rec.source)
print(f"best: {best.rid} {best.score:.1f} us | {best.genome.describe()}")
print(f"artifacts in {wd}/: population.json, logbook.json, state.json, "
      f"events.jsonl, kernels/*.py")
counts = sci.events.counts()
print(f"{sci.service.submissions} sequential submissions "
      f"({len(sci.population)} kernels), "
      f"{counts.get('retry', 0)} retries, "
      f"{counts.get('fallback', 0)} rule-based fallbacks")
