"""Full Kernel Scientist run with persisted artifacts: population JSON,
generation logbook, JSONL event log, eval cache, and every generated kernel
source.

    PYTHONPATH=src python examples/kernel_scientist_run.py --generations 20

The campaign checkpoints after every submission, so an interrupted run
(crash, Ctrl-C, preemption) continues where it left off:

    PYTHONPATH=src python examples/kernel_scientist_run.py --resume

The evaluation backend is built explicitly and handed to the scientist as
``backend=`` (the ``EvalBackend`` surface).  ``--workers 3`` evaluates the
three writer outputs of each generation concurrently on three independent
evaluation services (the per-service sequential contract of §3.4 stays
intact — the pool is what scales); ``--transport subprocess`` isolates each
worker in its own Python process behind the JSONL wire protocol, so a
worker death mid-benchmark costs one requeue instead of the campaign
(rehearse that with ``--kill-rate 0.2``).  ``--cache-max-entries N`` caps
the content-addressed eval cache as an LRU with on-disk compaction;
``--no-eval-cache`` disables it entirely.  ``--fault-rate 0.2`` wraps the
backends in the seeded fault injectors to rehearse the paper's
flaky-shared-queue regime (§3.4) end to end.

The verdict-trust layer (``core.integrity``) is off by default and enabled
per component: ``--quorum-k 3`` audits improbable timings with a
median-of-k re-measure quorum, ``--canary-interval 2`` runs the per-worker
drift sentinel every 2 generations, ``--quarantine-after 3`` blacklists a
kernel's content hash after it kills 3 workers, and
``--budget-submissions N`` stops the campaign cleanly at a submission
budget.  The configuration and all integrity state persist in the
campaign's ``state.json``, so a resumed run continues audits, quarantines,
and budgets where the killed one left off.
"""
import argparse
import pathlib

from repro.core import (CrashService, EvalCache, EvalPool, EvaluationService,
                        FlakyLLM, FlakyService, Integrity, KernelScientist,
                        NO_WAIT_POLICY, ScriptedLLM)

ap = argparse.ArgumentParser()
ap.add_argument("--generations", type=int, default=20)
ap.add_argument("--workdir", default="results/scientist_run")
ap.add_argument("--noise", type=float, default=0.0,
                help="benchmark jitter sigma (platform realism)")
ap.add_argument("--seed", type=int, default=0)
ap.add_argument("--resume", action="store_true",
                help="continue the campaign persisted in --workdir")
ap.add_argument("--fault-rate", type=float, default=0.0,
                help="injected transient-failure rate for LLM + eval queue")
ap.add_argument("--workers", type=int, default=1,
                help="concurrent evaluation services (default: the "
                     "single-worker sequential behaviour)")
ap.add_argument("--transport", choices=("inprocess", "subprocess"),
                default="inprocess",
                help="run eval workers as threads in this process or as "
                     "isolated subprocess workers (crash containment)")
ap.add_argument("--kill-rate", type=float, default=0.0,
                help="injected worker-death rate (requires "
                     "--transport subprocess; deaths requeue the job)")
ap.add_argument("--cache-max-entries", type=int, default=None,
                help="LRU cap for the eval cache (default: unbounded)")
ap.add_argument("--no-eval-cache", action="store_true",
                help="disable the content-addressed eval result cache")
ap.add_argument("--quorum-k", type=int, default=0,
                help="timing-audit quorum size: flagged verdicts are "
                     "re-measured k times and median-merged (0 = off)")
ap.add_argument("--canary-interval", type=int, default=0,
                help="run the per-worker drift sentinel every N "
                     "generations (0 = off)")
ap.add_argument("--quarantine-after", type=int, default=0,
                help="blacklist a kernel's content hash after it kills "
                     "this many workers (0 = off)")
ap.add_argument("--budget-submissions", type=int, default=None,
                help="stop the campaign at a generation boundary once "
                     "this many platform submissions are consumed")
args = ap.parse_args()

if args.kill_rate and args.transport != "subprocess":
    ap.error("--kill-rate kills whole workers; it needs "
             "--transport subprocess to be survivable")

llm = ScriptedLLM(seed=args.seed)
service = EvaluationService(noise=args.noise, seed=args.seed)
if args.fault_rate:
    llm = FlakyLLM(llm, seed=args.seed, error_rate=args.fault_rate / 2,
                   malformed_rate=args.fault_rate / 2)
    service = FlakyService(service, seed=args.seed,
                           error_rate=args.fault_rate)
if args.kill_rate:
    service = CrashService(service, seed=args.seed,
                           crash_rate=args.kill_rate)

wd = pathlib.Path(args.workdir)
cache = (None if args.no_eval_cache else
         EvalCache(wd / "eval_cache.jsonl",
                   max_entries=args.cache_max_entries))
backend = EvalPool.of(service, workers=args.workers, cache=cache,
                      retry_policy=NO_WAIT_POLICY,
                      transport=args.transport)
# all-defaults Integrity() = every component off = previous behaviour;
# resume() needs the same configuration the original run had (the live
# state — quarantine set, breaker states, canary reference, audit ledger,
# consumed wall-clock — is restored from state.json)
integrity = Integrity(quorum_k=args.quorum_k,
                      canary_interval=args.canary_interval,
                      quarantine_after=args.quarantine_after,
                      budget_submissions=args.budget_submissions)
if args.resume:
    sci = KernelScientist.resume(args.workdir, llm=llm, backend=backend,
                                 retry_policy=NO_WAIT_POLICY,
                                 integrity=integrity)
    print(f"resumed: {len(sci.logbook)} generations, "
          f"{len(sci.population)} kernels already on disk")
    # --generations is the campaign total; run() counts *additional*
    # generations (a resumed in-flight generation counts as one of them)
    todo = max(0, args.generations - len(sci.logbook))
else:
    sci = KernelScientist(llm=llm, backend=backend, workdir=args.workdir,
                          retry_policy=NO_WAIT_POLICY, integrity=integrity)
    todo = args.generations
best = sci.run(generations=todo)

(wd / "kernels").mkdir(exist_ok=True)
for rec in sci.population:
    (wd / "kernels" / f"{rec.rid}.py").write_text(rec.source)
print(f"best: {best.rid} {best.score:.1f} us | {best.genome.describe()}")
print(f"artifacts in {wd}/: population.json, logbook.json, state.json, "
      f"events.jsonl, eval_cache.jsonl, kernels/*.py")
counts = sci.events.counts()
stats = sci.pool.stats()
sci.pool.close()
print(f"{stats['submissions']} platform submissions across "
      f"{stats['workers']} {stats['transport']} worker(s) "
      f"({len(sci.population)} kernels, "
      f"{stats.get('cache_hits', 0)} cache hits / "
      f"{stats.get('cache_misses', 0)} misses, "
      f"{stats.get('cache_evictions', 0)} evictions), "
      f"{counts.get('retry', 0)} retries, "
      f"{counts.get('worker_died', 0)} worker deaths / "
      f"{counts.get('worker_requeue', 0)} requeues, "
      f"{counts.get('fallback', 0)} rule-based fallbacks")
if integrity.enabled:
    print(f"integrity: {counts.get('audit_flag', 0)} audit flags / "
          f"{counts.get('audit_quorum', 0)} quorums, "
          f"{counts.get('quarantine_add', 0)} quarantined / "
          f"{counts.get('quarantine_block', 0)} blocked, "
          f"{counts.get('canary', 0)} canaries / "
          f"{counts.get('worker_drift', 0)} drifts, "
          f"{counts.get('budget_stop', 0)} budget stops")
