"""Full Kernel Scientist run with persisted artifacts: population JSON,
generation logbook, and every generated kernel source.

    PYTHONPATH=src python examples/kernel_scientist_run.py --generations 20
"""
import argparse
import pathlib

from repro.core import EvaluationService, KernelScientist, ScriptedLLM

ap = argparse.ArgumentParser()
ap.add_argument("--generations", type=int, default=20)
ap.add_argument("--workdir", default="results/scientist_run")
ap.add_argument("--noise", type=float, default=0.0,
                help="benchmark jitter sigma (platform realism)")
ap.add_argument("--seed", type=int, default=0)
args = ap.parse_args()

sci = KernelScientist(
    llm=ScriptedLLM(seed=args.seed),
    service=EvaluationService(noise=args.noise, seed=args.seed),
    workdir=args.workdir)
best = sci.run(generations=args.generations)

wd = pathlib.Path(args.workdir)
(wd / "kernels").mkdir(exist_ok=True)
for rec in sci.population:
    (wd / "kernels" / f"{rec.rid}.py").write_text(rec.source)
print(f"best: {best.rid} {best.score:.1f} us | {best.genome.describe()}")
print(f"artifacts in {wd}/: population.json, logbook.json, kernels/*.py")
print(f"{sci.service.submissions} sequential submissions "
      f"({len(sci.population)} kernels)")
