"""Continuous-batching serving demo over the family-generic engine.

    PYTHONPATH=src python examples/serve_lm.py
"""
import sys

from repro.launch.serve import main

sys.exit(main(["--arch", "qwen2.5-3b", "--requests", "6", "--slots", "3",
               "--max-new", "8"]))
