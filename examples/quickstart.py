"""Quickstart: run the GPU Kernel Scientist for a few generations on the
TPU-v5e analytic evaluation platform and print the paper-Table-1 view.

    PYTHONPATH=src python examples/quickstart.py
"""
from repro.core import EvaluationService, KernelScientist, ScriptedLLM

sci = KernelScientist(llm=ScriptedLLM(), service=EvaluationService())
best = sci.run(generations=8)

print("== population (paper Table 1 view) ==")
lib = sci.population.get("00001")
naive = sci.population.get("00002")
print(f"library reference : {lib.score:9.1f} us (paper: ~850 us on MI300)")
print(f"naive translation : {naive.score:9.1f} us "
      f"({naive.score / lib.score:.1f}x library; paper: ~5.9x)")
print(f"scientist best    : {best.score:9.1f} us "
      f"({best.score / lib.score:.2f}x library; paper: ~0.53x)")
print(f"best kernel       : {best.genome.describe()}")
print()
print("== discovery curve ==")
for gen, us in sci.trajectory():
    bar = "#" * int(60 * lib.score / us * 0.5)
    print(f"gen {gen:2d}  {us:8.1f} us  {bar}")
print()
print("== last selection rationale (paper A.1 schema) ==")
print(sci.logbook[-1].selection["rationale"])
