"""End-to-end training driver: a ~25M-parameter qwen2.5-family model for a
few hundred steps on the synthetic copy-structured corpus (loss drops well
below the unigram entropy), with checkpoints + resume.

    PYTHONPATH=src python examples/train_lm.py
"""
import sys

from repro.launch.train import main

sys.exit(main([
    "--arch", "qwen2.5-3b", "--reduced",
    "--d-model", "256", "--n-layers", "4",
    "--steps", "300", "--batch", "8", "--seq", "128",
    "--peak-lr", "3e-3",
    "--ckpt-dir", "results/train_lm_ckpt", "--ckpt-every", "100",
]))
