"""Process-wide mesh registry + sharding-spec inference.

Two layers of API:

* **Inside traced model code** — ``shard_named(x, ("D", "T", "-", "-"))``
  attaches a ``with_sharding_constraint`` built from a compact axis tuple:
  ``"D"`` = batch-like (the ``data`` axis, folded with ``pod`` when both
  exist), ``"T"`` = tensor-parallel (the ``model`` axis), ``"-"`` =
  replicated.  When no mesh is registered (single-device tests, CPU smoke)
  every call is a strict no-op, so the single-device path is untouched.

* **At launch time** — ``param_specs`` / ``batch_specs`` / ``cache_specs``
  walk a pytree and return a matching tree of ``PartitionSpec``; the
  launchers wrap those in ``NamedSharding`` for ``device_put`` /
  ``in_shardings``.

Every axis assignment is divisibility-checked against the mesh, so the
same inference runs unchanged on the ``(16, 16)`` production mesh, the
``(2, 2, 2)`` debug mesh, and a ``(1, 1)`` single-device mesh (where it
degenerates to full replication).  Specs only ever read ``mesh.shape``,
so any mesh-shaped mapping (including an abstract stand-in) works for
spec inference; a concrete ``jax.sharding.Mesh`` is needed only once the
specs are turned into ``NamedSharding``s.
"""
from __future__ import annotations

import math
from typing import Optional, Sequence

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

# ---------------------------------------------------------------------------
# Current-mesh registry
# ---------------------------------------------------------------------------
_CURRENT_MESH = None


def set_mesh(mesh) -> None:
    """Register `mesh` as the process-wide current mesh (None clears it)."""
    global _CURRENT_MESH
    _CURRENT_MESH = mesh


def get_mesh():
    return _CURRENT_MESH


# ---------------------------------------------------------------------------
# Axis resolution
# ---------------------------------------------------------------------------
def _axis_sizes(mesh) -> dict:
    return dict(mesh.shape)


def _data_axes(mesh, dim_size: int) -> Optional[tuple]:
    """Mesh axes to shard a batch-like dim over: (pod, data) folded when the
    product divides, else whichever single axis divides, else None."""
    sizes = _axis_sizes(mesh)
    pod, data = sizes.get("pod", 0), sizes.get("data", 0)
    if pod > 1 and data > 1 and dim_size % (pod * data) == 0:
        return ("pod", "data")
    if data > 1 and dim_size % data == 0:
        return ("data",)
    if pod > 1 and dim_size % pod == 0:
        return ("pod",)
    return None


def _entry(axes: Optional[tuple]):
    if not axes:
        return None
    return axes if len(axes) > 1 else axes[0]


# ---------------------------------------------------------------------------
# In-graph constraints
# ---------------------------------------------------------------------------
def shard_named(x, axes: Sequence[str]):
    """Constrain `x` per a compact axis tuple ("D" | "T" | "-") — one tag
    per array dim.  No-op when no mesh is registered; tags that do not
    divide (or whose mesh axis is absent / already used) fall back to
    replicated for that dim."""
    mesh = _CURRENT_MESH
    if mesh is None:
        return x
    assert len(axes) == x.ndim, (axes, x.shape)
    sizes = _axis_sizes(mesh)
    used: set = set()
    spec = []
    for dim, tag in zip(x.shape, axes):
        entry = None
        if tag in ("D", "data"):
            data = _data_axes(mesh, dim)
            if data and not (set(data) & used):
                entry = _entry(data)
                used |= set(data)
        elif tag in ("T", "model"):
            m = sizes.get("model", 0)
            if m > 1 and dim % m == 0 and "model" not in used:
                entry = "model"
                used.add("model")
        elif tag != "-":
            raise ValueError(f"unknown shard tag {tag!r}")
        spec.append(entry)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*spec)))


def shard_activation(x):
    """Batch-major activation constraint: dim 0 over the data axes."""
    mesh = _CURRENT_MESH
    if mesh is None:
        return x
    data = _data_axes(mesh, x.shape[0])
    if data is None:
        return x
    spec = [_entry(data)] + [None] * (x.ndim - 1)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*spec)))


# ---------------------------------------------------------------------------
# Pytree spec inference
# ---------------------------------------------------------------------------
# Top-level keys whose leaves carry a leading stacked-layer dim (never
# sharded: the scan carries it).
_STACKED_KEYS = ("layers", "dense_layers", "super", "tail")
# Row-parallel weights: shard the *input* (second-to-last) dim so the
# column-parallel -> row-parallel pair needs one reduce, no resharding.
# embed/unembed live here because their first dim is the vocab dim.
_ROW_PARALLEL = ("wo", "w_down", "w_out", "ws_down", "embed", "unembed")
# MoE expert stacks (E, d_in, d_out): expert-parallel over `model`.
_EXPERT_STACKS = ("w_gate", "w_up", "w_down")

# Leaves whose per-layer body is smaller than this stay replicated: norms,
# biases, router tables — the all-gather would cost more than it saves.
MIN_SHARD_ELEMS = 4096
# FSDP (second dim over `data`) only pays off for genuinely large weights.
FSDP_MIN_ELEMS = 1 << 20


def _path_keys(path) -> list:
    keys = []
    for k in path:
        if hasattr(k, "key"):
            keys.append(str(k.key))
        elif hasattr(k, "idx"):
            keys.append(str(k.idx))
        else:  # pragma: no cover - future key types
            keys.append(str(k))
    return keys


def param_specs(params, mesh, mode: str = "train"):
    """PartitionSpec tree for a parameter pytree.

    mode="train": large matmul weights tensor-parallel over ``model``, with
    an FSDP shard of the other dim over ``data`` for very large leaves (the
    AdamW moment trees inherit these specs, so optimizer state is sharded).
    mode="serve": weight-stationary wide TP — the TP dim is folded over
    (``data``, ``model``) when it divides, so decode never re-gathers
    weights per token.  Small leaves replicate; the ``pod`` axis is always
    pure data-parallel for parameters.
    """
    assert mode in ("train", "serve"), mode
    sizes = _axis_sizes(mesh)
    msize = sizes.get("model", 0)
    dsize = sizes.get("data", 0)

    def tp_axes(dim_size: int):
        """Axes for the tensor-parallel dim, widest first in serve mode."""
        if mode == "serve" and msize > 1 and dsize > 1 \
                and dim_size % (msize * dsize) == 0:
            return ("data", "model")
        if msize > 1 and dim_size % msize == 0:
            return ("model",)
        return None

    def one(path, leaf):
        keys = _path_keys(path)
        shape = tuple(leaf.shape)
        off = 1 if keys and keys[0] in _STACKED_KEYS else 0
        body = shape[off:]
        name = keys[-1] if keys else ""
        if len(body) < 2 or math.prod(body) < MIN_SHARD_ELEMS:
            return P()
        spec = [None] * len(shape)

        # MoE expert stacks: expert-parallel over `model` on the E dim.
        if len(body) == 3 and "moe" in keys and name in _EXPERT_STACKS:
            if msize > 1 and body[0] % msize == 0:
                spec[off] = "model"
            if mode == "train" and dsize > 1 \
                    and math.prod(body) >= FSDP_MIN_ELEMS \
                    and body[2] % dsize == 0:
                spec[off + 2] = "data"
            return P(*spec)

        a, b = len(shape) - 2, len(shape) - 1
        tp_dim, other = (a, b) if name in _ROW_PARALLEL else (b, a)
        axes = tp_axes(shape[tp_dim])
        if axes is None:  # fall back to the other dim
            axes = tp_axes(shape[other])
            if axes is None:
                return P()
            tp_dim, other = other, tp_dim
        spec[tp_dim] = _entry(axes)
        if mode == "train" and dsize > 1 and other >= off \
                and "data" not in axes \
                and math.prod(body) >= FSDP_MIN_ELEMS \
                and shape[other] % dsize == 0:
            spec[other] = "data"
        return P(*spec)

    return jax.tree_util.tree_map_with_path(one, params)


def batch_specs(batch, mesh):
    """Batch-like leaves over the data axes.  The batch dim is axis 0,
    except mrope ``positions`` (3, B, S) which carries it on axis 1."""
    def one(path, leaf):
        keys = _path_keys(path)
        name = keys[-1] if keys else ""
        shape = tuple(leaf.shape)
        ax = 1 if name == "positions" else 0
        if len(shape) <= ax:
            return P()
        data = _data_axes(mesh, shape[ax])
        if data is None:
            return P()
        spec = [None] * len(shape)
        spec[ax] = _entry(data)
        return P(*spec)

    return jax.tree_util.tree_map_with_path(one, batch)


def cache_specs(cache, mesh):
    """Decode/prefill cache layout: batch dim (axis 1; ``len`` is (B,))
    over the data axes, plus static channel dims over ``model`` where they
    divide — KV heads for attention caches, the latent dim for MLA, SSD
    state heads for mamba."""
    sizes = _axis_sizes(mesh)
    msize = sizes.get("model", 0)

    def one(path, leaf):
        keys = _path_keys(path)
        name = keys[-1] if keys else ""
        shape = tuple(leaf.shape)
        ax = 0 if name == "len" or len(shape) == 1 else 1
        spec = [None] * len(shape)
        if len(shape) > ax:
            data = _data_axes(mesh, shape[ax])
            if data:
                spec[ax] = _entry(data)
        if msize > 1:
            if name in ("k", "v") and len(shape) == 5 \
                    and shape[3] % msize == 0:
                spec[3] = "model"          # (L, B, S, Hkv, dh)
            elif name == "ckv" and len(shape) == 4 \
                    and shape[3] % msize == 0:
                spec[3] = "model"          # (L, B, S, kv_lora)
            elif name == "state" and len(shape) == 5 \
                    and shape[2] % msize == 0:
                spec[2] = "model"          # (L, B, H, N, P)
        return P(*spec)

    return jax.tree_util.tree_map_with_path(one, cache)
