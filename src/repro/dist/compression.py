"""Int8 cross-pod gradient averaging with error feedback.

Inter-pod links are an order of magnitude slower than in-pod ICI, so the
cross-pod all-reduce of data-parallel gradients is the one collective
worth quantising: each pod sends int8 values plus one f32 scale per leaf
(~4x fewer wire bytes than bf16, ~8x vs f32) and averages the dequantised
gathers locally.  The quantisation residual is carried in an error-feedback
state and added back into the next step's gradient, so the *accumulated*
compression error stays bounded by one quantisation step instead of
growing linearly (EF-SGD; Karimireddy et al., 2019).

    err = init_error_state(grads)
    mean, err = cross_pod_mean(grads, err, mesh)   # every step

Meshes without a ``pod`` axis (or with pod=1) skip the collective but keep
the quantise/dequantise + error-feedback arithmetic, so single-pod runs
exercise identical numerics.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

try:  # moved to jax.experimental.shard_map in 0.4.x
    from jax.experimental.shard_map import shard_map
except ImportError:  # pragma: no cover - newer jax
    from jax import shard_map

POD_AXIS = "pod"


def init_error_state(grads):
    """Zeroed f32 error-feedback residuals, one per gradient leaf."""
    return jax.tree.map(
        lambda g: jnp.zeros(jnp.shape(g), jnp.float32), grads)


def _quantise(v):
    """v (f32) -> (int8 codes, f32 scale); symmetric per-leaf scaling."""
    scale = jnp.maximum(jnp.max(jnp.abs(v)), 1e-30) / 127.0
    q = jnp.clip(jnp.round(v / scale), -127.0, 127.0).astype(jnp.int8)
    return q, scale


def _compress_leaf(g, e):
    """Returns (int8 codes, scale, new error residual)."""
    v = g.astype(jnp.float32) + e
    q, scale = _quantise(v)
    new_e = v - q.astype(jnp.float32) * scale
    return q, scale, new_e


def cross_pod_mean(grads, err, mesh, axis: str = POD_AXIS):
    """Error-feedback int8 mean of `grads` over the mesh's pod axis.

    Returns (mean tree matching grads' dtypes, new error state).  The wire
    payload per pod is the int8 code tensor + one f32 scale per leaf; the
    mean is reconstructed from the all-gathered (codes, scales) pairs.
    """
    n_pods = dict(mesh.shape).get(axis, 1)
    leaves, treedef = jax.tree.flatten(grads)
    e_leaves = treedef.flatten_up_to(err)

    if n_pods <= 1:
        out = [_compress_leaf(g, e) for g, e in zip(leaves, e_leaves)]
        means = [(q.astype(jnp.float32) * s).astype(g.dtype)
                 for (q, s, _), g in zip(out, leaves)]
        return treedef.unflatten(means), treedef.unflatten(
            [o[2] for o in out])

    @functools.partial(
        shard_map, mesh=mesh, in_specs=(P(), P()), out_specs=(P(), P()),
        check_rep=False)
    def _mean_ef(g_tree, e_tree):
        gs = treedef.flatten_up_to(g_tree)
        es = treedef.flatten_up_to(e_tree)
        means, new_es = [], []
        for g, e in zip(gs, es):
            q, scale, new_e = _compress_leaf(g, e)
            # wire: int8 codes + scalar scale, gathered across pods
            qs = jax.lax.all_gather(q, axis)               # (P, ...)
            ss = jax.lax.all_gather(scale, axis)           # (P,)
            deq = qs.astype(jnp.float32) * ss.reshape((-1,) + (1,) * q.ndim)
            means.append(jnp.mean(deq, axis=0).astype(g.dtype))
            new_es.append(new_e)
        return treedef.unflatten(means), treedef.unflatten(new_es)

    return _mean_ef(grads, err)


def wire_bytes(grads) -> dict:
    """Per-step cross-pod payload: compressed vs raw (diagnostics)."""
    n = sum(leaf.size for leaf in jax.tree.leaves(grads))
    raw = sum(leaf.size * jnp.dtype(leaf.dtype).itemsize
              for leaf in jax.tree.leaves(grads))
    n_leaves = len(jax.tree.leaves(grads))
    return {"compressed": n + 4 * n_leaves, "raw": int(raw),
            "ratio": float(raw) / max(n + 4 * n_leaves, 1)}
