"""Distribution subsystem: mesh registry, sharding-spec inference, and
compressed cross-pod collectives.

``partition`` is the single place the rest of the codebase asks "how is
this tensor laid out on the current mesh?" — models call ``shard_named`` /
``shard_activation`` on activations, launchers call ``param_specs`` /
``batch_specs`` / ``cache_specs`` to place whole pytrees.  ``compression``
implements int8 error-feedback gradient averaging over the ``pod`` axis
(the slow inter-pod links are the one place quantising the wire pays).
"""
from . import compression, partition
from .partition import (
    batch_specs, cache_specs, get_mesh, param_specs, set_mesh,
    shard_activation, shard_named,
)

__all__ = [
    "compression", "partition", "set_mesh", "get_mesh", "shard_named",
    "shard_activation", "param_specs", "batch_specs", "cache_specs",
]
