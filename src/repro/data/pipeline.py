"""Deterministic synthetic token pipeline, sharded and straggler-free.

Design for scale: batches are a pure function of (seed, step, shard), so
any host can (re)produce its shard without coordination — restarts, elastic
re-scales and straggler exclusion never need a data-service checkpoint, and
there is no dynamic work queue to skew step times.  A real corpus pipeline
drops in behind the same ``__iter__``/``at_step`` interface.
"""
from __future__ import annotations

import dataclasses

import jax
import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    inputs: str = "tokens"           # "tokens" | "embeddings"
    d_model: int = 0                 # for embedding inputs
    mrope: bool = False


class SyntheticLM:
    """Zipf-ish synthetic LM stream with shifted-label structure (so loss
    actually decreases during integration tests)."""

    def __init__(self, cfg: DataConfig, shard_index: int = 0,
                 shard_count: int = 1):
        assert cfg.global_batch % shard_count == 0
        self.cfg = cfg
        self.shard_index = shard_index
        self.shard_count = shard_count
        self.local_batch = cfg.global_batch // shard_count

    def at_step(self, step: int) -> dict:
        cfg = self.cfg
        rng = np.random.default_rng(
            np.random.SeedSequence(
                [cfg.seed, step, self.shard_index]))
        # zipfian marginals + a copy pattern: token[t] repeats token[t-1]
        # with p=0.5, giving the model something learnable
        ranks = rng.zipf(1.3, size=(self.local_batch, cfg.seq_len + 1))
        tokens = np.minimum(ranks, cfg.vocab - 1).astype(np.int32)
        copy_mask = rng.random((self.local_batch, cfg.seq_len + 1)) < 0.5
        for t in range(1, cfg.seq_len + 1):
            tokens[:, t] = np.where(copy_mask[:, t], tokens[:, t - 1],
                                    tokens[:, t])
        batch = {"labels": tokens[:, 1:].copy()}
        if cfg.inputs == "embeddings":
            emb_rng = np.random.default_rng(cfg.seed + 7)
            table = emb_rng.standard_normal(
                (min(cfg.vocab, 4096), cfg.d_model)).astype(np.float32) * 0.02
            batch["embeds"] = table[tokens[:, :-1] % table.shape[0]]
        else:
            batch["tokens"] = tokens[:, :-1].copy()
        if cfg.mrope:
            pos = np.broadcast_to(
                np.arange(cfg.seq_len, dtype=np.int32),
                (self.local_batch, cfg.seq_len))
            batch["positions"] = np.stack([pos, pos * 0, pos * 0], 0)
        return batch

    def __iter__(self):
        step = 0
        while True:
            yield self.at_step(step)
            step += 1


def device_put_batch(batch: dict, shardings) -> dict:
    """Place a host batch onto the mesh with the given sharding tree."""
    return jax.tree.map(
        lambda x, s: jax.device_put(x, s), batch, shardings)
