from .pipeline import DataConfig, SyntheticLM, device_put_batch  # noqa: F401
