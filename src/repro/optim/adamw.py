"""AdamW with global-norm clipping, fp32 moments over bf16 params (ZeRO:
the moment trees inherit the FSDP parameter sharding, so optimizer state is
fully sharded across the mesh)."""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0


def init(params):
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "count": jnp.zeros((), jnp.int32),
    }


def global_norm(tree):
    return jnp.sqrt(sum(
        jnp.sum(jnp.square(x.astype(jnp.float32)))
        for x in jax.tree.leaves(tree)))


def update(grads, state, params, lr, cfg: AdamWConfig = AdamWConfig()):
    """Returns (new_params, new_state, metrics)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-12))
    count = state["count"] + 1
    c1 = 1.0 - cfg.b1 ** count.astype(jnp.float32)
    c2 = 1.0 - cfg.b2 ** count.astype(jnp.float32)

    def upd(g, m, v, p):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1.0 - cfg.b1) * g
        v = cfg.b2 * v + (1.0 - cfg.b2) * g * g
        step = (m / c1) / (jnp.sqrt(v / c2) + cfg.eps)
        # decoupled weight decay on matrices only (ndim >= 2)
        wd = cfg.weight_decay if p.ndim >= 2 else 0.0
        new_p = p.astype(jnp.float32) - lr * (step + wd * p.astype(jnp.float32))
        return new_p.astype(p.dtype), m, v

    flat_g, treedef = jax.tree.flatten(grads)
    flat_m = treedef.flatten_up_to(state["m"])
    flat_v = treedef.flatten_up_to(state["v"])
    flat_p = treedef.flatten_up_to(params)
    out = [upd(g, m, v, p) for g, m, v, p in
           zip(flat_g, flat_m, flat_v, flat_p)]
    new_params = treedef.unflatten([o[0] for o in out])
    new_state = {
        "m": treedef.unflatten([o[1] for o in out]),
        "v": treedef.unflatten([o[2] for o in out]),
        "count": count,
    }
    return new_params, new_state, {"grad_norm": gnorm}
