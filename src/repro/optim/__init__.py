from . import adamw, schedule  # noqa: F401
from .adamw import AdamWConfig  # noqa: F401
