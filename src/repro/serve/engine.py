"""Continuous-batching serving engine.

Slot-based scheduler over the family-generic model API: new requests are
prefilled one at a time into a free slot of the shared padded cache;
every engine tick runs one fused decode step across all active slots;
finished requests free their slot immediately (no head-of-line blocking).
This is the serving analogue of the paper's evaluation loop — sequential
admission, batched execution.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec

from repro.dist import partition
from repro.models import api
from repro.models.config import ArchConfig


def _named(tree, mesh):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), tree,
                        is_leaf=lambda x: isinstance(x, PartitionSpec))


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray               # (S,) int32
    max_new: int = 16
    eos_id: Optional[int] = None
    generated: list = dataclasses.field(default_factory=list)
    slot: Optional[int] = None

    @property
    def done(self) -> bool:
        if self.eos_id is not None and self.generated \
                and self.generated[-1] == self.eos_id:
            return True
        return len(self.generated) >= self.max_new


def _batch_axis(key: str) -> int:
    return 0 if key == "len" else 1


class Engine:
    def __init__(self, cfg: ArchConfig, params, *, slots: int = 4,
                 max_seq: int = 512, prefill_pad: int = 1, mesh=None):
        assert not cfg.encoder_only, "encoder-only models cannot serve"
        self.cfg = cfg
        self.params = params
        self.slots = slots
        self.max_seq = max_seq
        self.prefill_pad = prefill_pad
        self.mesh = mesh
        self.cache = api.init_cache(cfg, slots, max_seq,
                                    dtype=jnp.dtype(cfg.param_dtype))
        if mesh is not None:
            # register the mesh for in-graph shard_named constraints and
            # place weights once, weight-stationary (serve-mode wide TP)
            partition.set_mesh(mesh)
            self.params = jax.device_put(
                params,
                _named(partition.param_specs(params, mesh, mode="serve"),
                       mesh))
            self.cache = jax.device_put(
                self.cache,
                _named(partition.cache_specs(self.cache, mesh), mesh))
        self.free = deque(range(slots))
        self.active: dict[int, Request] = {}
        self.queue: deque[Request] = deque()
        self.finished: list[Request] = []

        self._prefill = jax.jit(
            lambda p, b: api.prefill(p, cfg, b, max_seq))
        self._decode = jax.jit(
            lambda p, c, t: api.decode_step(p, cfg, c, t))

    # ------------------------------------------------------------- intake
    def submit(self, req: Request) -> None:
        self.queue.append(req)

    def _admit(self) -> None:
        while self.queue and self.free:
            req = self.queue.popleft()
            slot = self.free.popleft()
            req.slot = slot
            s = len(req.prompt)
            pad = -s % self.prefill_pad
            toks = np.pad(req.prompt, (0, pad))
            batch = {"tokens": jnp.asarray(toks, jnp.int32)[None]}
            if self.cfg.mrope:
                pos = jnp.arange(toks.shape[0], dtype=jnp.int32)[None]
                batch["positions"] = jnp.stack([pos, pos * 0, pos * 0], 0)
            logits, cache1 = self._prefill(self.params, batch)
            cache1 = dict(cache1)
            cache1["len"] = jnp.full((1,), s + pad, jnp.int32)
            self._write_slot(slot, cache1)
            if pad == 0:   # last-position logits are the first new token
                req.generated.append(int(jnp.argmax(logits[0])))
            self.active[slot] = req

    def _write_slot(self, slot: int, cache1) -> None:
        def put(dst, src, key):
            ax = _batch_axis(key)
            idx = [slice(None)] * dst.ndim
            idx[ax] = slice(slot, slot + 1)
            return dst.at[tuple(idx)].set(src)

        self.cache = {k: put(self.cache[k], cache1[k], k)
                      for k in self.cache}

    # --------------------------------------------------------------- tick
    def tick(self) -> int:
        """Admit, run one decode step for all active slots, retire done."""
        self._admit()
        if not self.active:
            return 0
        tokens = np.zeros((self.slots,), np.int32)
        for slot, req in self.active.items():
            tokens[slot] = (req.generated[-1] if req.generated
                            else req.prompt[-1])
        logits, self.cache = self._decode(
            self.params, self.cache, jnp.asarray(tokens))
        nxt = np.asarray(jnp.argmax(logits, axis=-1))
        for slot in list(self.active):
            req = self.active[slot]
            req.generated.append(int(nxt[slot]))
            if req.done:
                del self.active[slot]
                self.free.append(slot)
                self.finished.append(req)
        return len(self.active)

    def run(self, max_ticks: int = 1000) -> list:
        ticks = 0
        while (self.queue or self.active) and ticks < max_ticks:
            self.tick()
            ticks += 1
        return self.finished
