"""Model zoo: the 10 assigned architectures across 5 families."""
from . import api  # noqa: F401
from .config import (  # noqa: F401
    SHAPES, ArchConfig, MLAConfig, MoEConfig, RGLRUConfig, ShapeConfig,
    SSMConfig,
)
