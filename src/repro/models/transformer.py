"""Decoder/encoder transformer covering the dense, MoE and MLA families
(qwen1.5/2.5/3, stablelm, command-r+, qwen2-vl, hubert, deepseek-v2).

Layers are stacked and scanned (compile time independent of depth); each
layer body is optionally rematerialised.  Attention is the FLOP-exact
blockwise formulation from ``common.py``; MLA decode uses the absorbed
matmul identity so the latent cache is never expanded to per-head keys.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.dist import partition as _dist

from .common import (
    KeyGen, apply_mrope, apply_rope, blockwise_attention, chunked_softmax_xent,
    decode_attention_xla, dense_init, rms_norm,
)
from .config import ArchConfig
from .moe import init_moe_ffn, moe_ffn


# ---------------------------------------------------------------------------
# Parameter initialisation
# ---------------------------------------------------------------------------
def _init_attention(kg: KeyGen, cfg: ArchConfig, dtype):
    d, h, hkv, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim_
    if cfg.family == "mla":
        m = cfg.mla
        return {
            "wq_a": dense_init(kg(), (d, m.q_lora), dtype=dtype),
            "q_ln": jnp.zeros((m.q_lora,), dtype),
            "wq_b": dense_init(kg(), (m.q_lora, h * (m.d_nope + m.d_rope)),
                               dtype=dtype),
            "wkv_a": dense_init(kg(), (d, m.kv_lora + m.d_rope), dtype=dtype),
            "kv_ln": jnp.zeros((m.kv_lora,), dtype),
            "wk_b": dense_init(kg(), (m.kv_lora, h * m.d_nope), dtype=dtype),
            "wv_b": dense_init(kg(), (m.kv_lora, h * m.v_head_dim), dtype=dtype),
            "wo": dense_init(kg(), (h * m.v_head_dim, d), dtype=dtype),
        }
    p = {
        "wq": dense_init(kg(), (d, h * dh), dtype=dtype),
        "wk": dense_init(kg(), (d, hkv * dh), dtype=dtype),
        "wv": dense_init(kg(), (d, hkv * dh), dtype=dtype),
        "wo": dense_init(kg(), (h * dh, d), dtype=dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((h * dh,), dtype)
        p["bk"] = jnp.zeros((hkv * dh,), dtype)
        p["bv"] = jnp.zeros((hkv * dh,), dtype)
    return p


def _init_dense_ffn(kg: KeyGen, d: int, f: int, dtype):
    return {
        "w_gate": dense_init(kg(), (d, f), dtype=dtype),
        "w_up": dense_init(kg(), (d, f), dtype=dtype),
        "w_down": dense_init(kg(), (f, d), dtype=dtype),
    }


def _init_layer(kg: KeyGen, cfg: ArchConfig, dtype, *, moe_layer: bool):
    p = {
        "ln1": jnp.zeros((cfg.d_model,), dtype),
        "ln2": jnp.zeros((cfg.d_model,), dtype),
        "attn": _init_attention(kg, cfg, dtype),
    }
    if moe_layer:
        p["moe"] = init_moe_ffn(kg, cfg.d_model, cfg.moe, dtype)
    else:
        f = (cfg.moe.d_ff_dense if (cfg.moe and cfg.moe.n_dense_layers)
             else cfg.d_ff)
        p["ffn"] = _init_dense_ffn(kg, cfg.d_model, f, dtype)
    return p


def _stack(trees):
    return jax.tree.map(lambda *xs: jnp.stack(xs), *trees)


def init_params(cfg: ArchConfig, key):
    dtype = jnp.dtype(cfg.param_dtype)
    kg = KeyGen(key)
    vp = cfg.vocab_padded
    params = {
        "embed": dense_init(kg(), (vp, cfg.d_model), in_axis=1, dtype=dtype),
        "ln_f": jnp.zeros((cfg.d_model,), dtype),
    }
    if not cfg.tie_embeddings:
        params["unembed"] = dense_init(kg(), (vp, cfg.d_model), in_axis=1,
                                       dtype=dtype)
    n_dense = cfg.moe.n_dense_layers if cfg.moe else 0
    is_moe = cfg.moe is not None
    if n_dense:
        params["dense_layers"] = _stack(
            [_init_layer(kg, cfg, dtype, moe_layer=False)
             for _ in range(n_dense)])
    params["layers"] = _stack(
        [_init_layer(kg, cfg, dtype, moe_layer=is_moe)
         for _ in range(cfg.n_layers - n_dense)])
    return params


# ---------------------------------------------------------------------------
# Attention
# ---------------------------------------------------------------------------
def _rope(cfg: ArchConfig, x, positions):
    if cfg.mrope:
        return apply_mrope(x, positions, cfg.rope_theta)
    return apply_rope(x, positions, cfg.rope_theta)


def _split_heads(x, h):
    b, s, hd = x.shape
    return x.reshape(b, s, h, hd // h).transpose(0, 2, 1, 3)   # (B,H,S,dh)


def attention_seq(p, x, positions, cfg: ArchConfig, *, kv_len=None):
    """Full-sequence attention (train / prefill).  Returns (y, (k, v))."""
    b, s, d = x.shape
    h, hkv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim_
    if cfg.family == "mla":
        return _mla_seq(p, x, positions, cfg, kv_len=kv_len)
    q = jnp.einsum("bsd,dk->bsk", x, p["wq"])
    k = jnp.einsum("bsd,dk->bsk", x, p["wk"])
    v = jnp.einsum("bsd,dk->bsk", x, p["wv"])
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q, k, v = _split_heads(q, h), _split_heads(k, hkv), _split_heads(v, hkv)
    q = _rope(cfg, q, positions)
    k = _rope(cfg, k, positions)
    y = blockwise_attention(
        q, k, v, causal=not cfg.encoder_only, kv_len=kv_len,
        q_chunk=cfg.attn_q_chunk, k_chunk=cfg.attn_k_chunk,
        unroll=cfg.exact_count)
    y = y.transpose(0, 2, 1, 3).reshape(b, s, h * dh)
    return jnp.einsum("bsk,kd->bsd", y, p["wo"]), (k, v)


def attention_decode(p, x, positions, cfg: ArchConfig, cache_k, cache_v,
                     kv_len):
    """x: (B, D) one token; cache_k/v: (B, Smax, Hkv, dh); writes at kv_len.
    Returns (y, new_k_cache, new_v_cache)."""
    b, d = x.shape
    h, hkv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim_
    q = (x @ p["wq"])
    k = (x @ p["wk"])
    v = (x @ p["wv"])
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(b, h, dh)
    k = k.reshape(b, hkv, dh)
    v = v.reshape(b, hkv, dh)
    pos = positions if positions.ndim else positions[None]
    q = _rope(cfg, q[:, :, None, :], pos[..., None] if cfg.mrope
              else pos[:, None])[:, :, 0, :]
    k = _rope(cfg, k[:, :, None, :], pos[..., None] if cfg.mrope
              else pos[:, None])[:, :, 0, :]

    def upd(cache, new):
        return jax.vmap(
            lambda c, n, i: jax.lax.dynamic_update_slice_in_dim(
                c, n[None], i, axis=0))(cache, new, kv_len)

    cache_k = upd(cache_k, k)                 # (B, Smax, Hkv, dh)
    cache_v = upd(cache_v, v)
    y = decode_attention_xla(
        q, cache_k.transpose(0, 2, 1, 3), cache_v.transpose(0, 2, 1, 3),
        kv_len + 1)
    y = y.reshape(b, h * dh)
    return y @ p["wo"], cache_k, cache_v


# ---------------------------------------------------------------------------
# MLA (deepseek-v2)
# ---------------------------------------------------------------------------
def _mla_q(p, x, positions, cfg):
    m = cfg.mla
    h = cfg.n_heads
    ql = rms_norm(jnp.einsum("...d,dk->...k", x, p["wq_a"]), p["q_ln"],
                  cfg.norm_eps)
    q = jnp.einsum("...k,kh->...h", ql, p["wq_b"])
    if x.ndim == 3:
        b, s, _ = x.shape
        q = q.reshape(b, s, h, m.d_nope + m.d_rope).transpose(0, 2, 1, 3)
        qn, qr = q[..., :m.d_nope], q[..., m.d_nope:]
        qr = apply_rope(qr, positions, cfg.rope_theta)
    else:
        b, _ = x.shape
        q = q.reshape(b, h, m.d_nope + m.d_rope)
        qn, qr = q[..., :m.d_nope], q[..., m.d_nope:]
        qr = apply_rope(qr[:, :, None, :], positions[:, None],
                        cfg.rope_theta)[:, :, 0, :]
    return qn, qr


def _mla_seq(p, x, positions, cfg: ArchConfig, kv_len=None):
    b, s, d = x.shape
    m, h = cfg.mla, cfg.n_heads
    qn, qr = _mla_q(p, x, positions, cfg)                    # (B,H,S,*)
    kv = jnp.einsum("bsd,dk->bsk", x, p["wkv_a"])
    ckv = rms_norm(kv[..., :m.kv_lora], p["kv_ln"], cfg.norm_eps)
    kr = kv[..., m.kv_lora:]                                 # (B,S,dr)
    kr = apply_rope(kr[:, None], positions, cfg.rope_theta)  # (B,1,S,dr)
    kn = jnp.einsum("bsk,kh->bsh", ckv, p["wk_b"]).reshape(
        b, s, h, m.d_nope).transpose(0, 2, 1, 3)
    v = jnp.einsum("bsk,kh->bsh", ckv, p["wv_b"]).reshape(
        b, s, h, m.v_head_dim).transpose(0, 2, 1, 3)
    q = jnp.concatenate([qn, qr], axis=-1)
    k = jnp.concatenate([kn, jnp.broadcast_to(kr, (b, h, s, m.d_rope))],
                        axis=-1)
    y = blockwise_attention(q, k, v, causal=True, kv_len=kv_len,
                            q_chunk=cfg.attn_q_chunk,
                            k_chunk=cfg.attn_k_chunk,
                            unroll=cfg.exact_count)
    y = y.transpose(0, 2, 1, 3).reshape(b, s, h * m.v_head_dim)
    # cache = the rope'd shared key + normalised latent, per token
    return jnp.einsum("bsk,kd->bsd", y, p["wo"]), (ckv, kr[:, 0])


def mla_decode(p, x, positions, cfg: ArchConfig, cache_ckv, cache_kr, kv_len):
    """Absorbed-matmul MLA decode: the latent cache is attended directly.
    x: (B, D); cache_ckv: (B, Smax, kv_lora); cache_kr: (B, Smax, d_rope)."""
    b, d = x.shape
    m, h = cfg.mla, cfg.n_heads
    qn, qr = _mla_q(p, x, positions, cfg)                    # (B,H,dn),(B,H,dr)
    kv = x @ p["wkv_a"]
    ckv = rms_norm(kv[..., :m.kv_lora], p["kv_ln"], cfg.norm_eps)  # (B,Lr)
    kr = apply_rope(kv[..., m.kv_lora:][:, None, None, :],
                    positions[:, None], cfg.rope_theta)[:, 0, 0, :]

    def upd(cache, new):
        return jax.vmap(
            lambda c, n, i: jax.lax.dynamic_update_slice_in_dim(
                c, n[None], i, axis=0))(cache, new, kv_len)

    cache_ckv = upd(cache_ckv, ckv)
    cache_kr = upd(cache_kr, kr)

    wk_b = p["wk_b"].reshape(m.kv_lora, h, m.d_nope)
    q_lat = jnp.einsum("bhn,lhn->bhl", qn, wk_b)             # absorb W_uk
    scale = 1.0 / jnp.sqrt(jnp.float32(m.d_nope + m.d_rope))
    logits = (jnp.einsum("bhl,bsl->bhs", q_lat, cache_ckv)
              + jnp.einsum("bhr,bsr->bhs", qr, cache_kr)) * scale
    s_max = cache_ckv.shape[1]
    valid = jax.lax.broadcasted_iota(jnp.int32, (b, s_max), 1) < (kv_len + 1)[:, None]
    logits = jnp.where(valid[:, None, :], logits.astype(jnp.float32), -1e30)
    probs = jax.nn.softmax(logits, axis=-1).astype(cache_ckv.dtype)
    latent = jnp.einsum("bhs,bsl->bhl", probs, cache_ckv)
    wv_b = p["wv_b"].reshape(m.kv_lora, h, m.v_head_dim)
    y = jnp.einsum("bhl,lhv->bhv", latent, wv_b)             # absorb W_uv
    y = y.reshape(b, h * m.v_head_dim)
    return y @ p["wo"], cache_ckv, cache_kr


# ---------------------------------------------------------------------------
# FFN + layer bodies
# ---------------------------------------------------------------------------
def ffn_dense(p, x):
    g = jnp.einsum("...d,df->...f", x, p["w_gate"])
    u = jnp.einsum("...d,df->...f", x, p["w_up"])
    return jnp.einsum("...f,fd->...d",
                      jax.nn.silu(g.astype(jnp.float32)).astype(u.dtype) * u,
                      p["w_down"])


def _layer_seq(lp, x, positions, cfg: ArchConfig, kv_len=None):
    y, kv = attention_seq(lp["attn"], rms_norm(x, lp["ln1"], cfg.norm_eps),
                          positions, cfg, kv_len=kv_len)
    x = x + y
    h = rms_norm(x, lp["ln2"], cfg.norm_eps)
    if "moe" in lp:
        b, s, d = h.shape
        y, aux = moe_ffn(lp["moe"], h.reshape(b * s, d), cfg.moe,
                         norm_topk=cfg.moe.n_shared == 0)
        y = y.reshape(b, s, d)
    else:
        y, aux = ffn_dense(lp["ffn"], h), {"moe_aux": jnp.zeros((), jnp.float32),
                                           "moe_z": jnp.zeros((), jnp.float32)}
    return x + y, aux, kv


def _layer_decode(lp, x, positions, cfg: ArchConfig, cache, kv_len):
    h = rms_norm(x, lp["ln1"], cfg.norm_eps)
    if cfg.family == "mla":
        y, c0, c1 = mla_decode(lp["attn"], h, positions, cfg,
                               cache[0], cache[1], kv_len)
    else:
        y, c0, c1 = attention_decode(lp["attn"], h, positions, cfg,
                                     cache[0], cache[1], kv_len)
    x = x + y
    h = rms_norm(x, lp["ln2"], cfg.norm_eps)
    if "moe" in lp:
        y, _ = moe_ffn(lp["moe"], h, cfg.moe,
                       norm_topk=cfg.moe.n_shared == 0)
    else:
        y = ffn_dense(lp["ffn"], h)
    return x + y, (c0, c1)


# ---------------------------------------------------------------------------
# Full model: forward / prefill / decode
# ---------------------------------------------------------------------------
def _embed_in(params, cfg: ArchConfig, batch):
    if cfg.inputs == "embeddings":
        return batch["embeds"]
    return params["embed"][batch["tokens"]]


def _positions(cfg: ArchConfig, batch, b, s):
    if "positions" in batch:
        return batch["positions"]
    pos = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    if cfg.mrope:
        pos = jnp.broadcast_to(pos, (3, b, s))
    return pos


def forward(params, cfg: ArchConfig, batch):
    """Returns (hidden (B,S,D), aux dict of scalars, kv caches (L,...))."""
    x = _embed_in(params, cfg, batch)
    b, s, _ = x.shape
    positions = _positions(cfg, batch, b, s)

    def body(lp, x):
        return _layer_seq(lp, x, positions, cfg)

    if cfg.remat:
        body = jax.checkpoint(body)

    aux0 = {"moe_aux": jnp.zeros((), jnp.float32),
            "moe_z": jnp.zeros((), jnp.float32)}

    def scan_fn(carry, lp):
        x, aux = carry
        x = _dist.shard_activation(x)
        x, aux2, kv = body(lp, x)
        return (x, jax.tree.map(jnp.add, aux, aux2)), kv

    carry = (x, aux0)
    kvs = []
    for _ in range(cfg.scan_repeats):   # >1 only in dry-run accounting mode
        kvs = []
        if "dense_layers" in params:
            carry, kv_d = jax.lax.scan(scan_fn, carry, params["dense_layers"])
            kvs.append(kv_d)
        carry, kv_m = jax.lax.scan(scan_fn, carry, params["layers"])
        kvs.append(kv_m)
    x, aux = carry
    x = rms_norm(x, params["ln_f"], cfg.norm_eps)
    if len(kvs) == 1:
        kv = kvs[0]
    else:
        kv = jax.tree.map(lambda a, b_: jnp.concatenate([a, b_], axis=0),
                          kvs[0], kvs[1])
    return x, aux, kv


def loss_fn(params, cfg: ArchConfig, batch):
    hidden, aux, _ = forward(params, cfg, batch)
    b, s, d = hidden.shape
    unembed = params.get("unembed", params["embed"])
    labels = batch["labels"].reshape(b * s)
    weights = batch.get("loss_weights")
    if weights is not None:
        weights = weights.reshape(b * s)
    nll, denom = chunked_softmax_xent(
        hidden.reshape(b * s, d), unembed, labels, weights,
        chunk=cfg.loss_chunk, unroll=cfg.exact_count)
    loss = nll / jnp.maximum(denom, 1.0)
    total = loss + 1e-2 * aux["moe_aux"] + 1e-3 * aux["moe_z"]
    return total, {"nll": loss, "moe_aux": aux["moe_aux"],
                   "moe_z": aux["moe_z"]}


def init_cache(cfg: ArchConfig, batch_size: int, max_seq: int,
               dtype=jnp.bfloat16):
    l = cfg.n_layers
    if cfg.family == "mla":
        m = cfg.mla
        return {
            "ckv": jnp.zeros((l, batch_size, max_seq, m.kv_lora), dtype),
            "kr": jnp.zeros((l, batch_size, max_seq, m.d_rope), dtype),
            "len": jnp.zeros((batch_size,), jnp.int32),
        }
    dh = cfg.head_dim_
    return {
        "k": jnp.zeros((l, batch_size, max_seq, cfg.n_kv_heads, dh), dtype),
        "v": jnp.zeros((l, batch_size, max_seq, cfg.n_kv_heads, dh), dtype),
        "len": jnp.zeros((batch_size,), jnp.int32),
    }


def prefill(params, cfg: ArchConfig, batch, max_seq: int):
    """Full-sequence forward that also builds the KV cache."""
    hidden, _, kv = forward(params, cfg, batch)
    b, s, d = hidden.shape
    unembed = params.get("unembed", params["embed"])
    last = hidden[:, -1, :]
    logits = jnp.einsum("bd,vd->bv", last, unembed,
                        preferred_element_type=jnp.float32)
    if cfg.encoder_only:
        return logits, None
    pad = max_seq - s
    if cfg.family == "mla":
        ckv, kr = kv
        cache = {
            "ckv": jnp.pad(ckv, ((0, 0), (0, 0), (0, pad), (0, 0))),
            "kr": jnp.pad(kr, ((0, 0), (0, 0), (0, pad), (0, 0))),
            "len": jnp.full((b,), s, jnp.int32),
        }
    else:
        k, v = kv  # (L, B, Hkv, S, dh) -> (L, B, S, Hkv, dh)
        k = jnp.pad(k.transpose(0, 1, 3, 2, 4),
                    ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v.transpose(0, 1, 3, 2, 4),
                    ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
        cache = {"k": k, "v": v, "len": jnp.full((b,), s, jnp.int32)}
    return logits, cache


def decode_step(params, cfg: ArchConfig, cache, tokens, positions=None):
    """One decode step.  tokens: (B,) int32 (or embeds (B, D)).
    Returns (logits (B, V), new cache)."""
    if cfg.inputs == "embeddings" and tokens.ndim == 2:
        x = tokens
    else:
        x = params["embed"][tokens]
    kv_len = cache["len"]
    b = x.shape[0]
    if positions is None:
        positions = kv_len
        if cfg.mrope:  # text continuation: t advances, h/w stay 0
            positions = jnp.stack([kv_len, kv_len * 0, kv_len * 0], 0)

    xs_dense = None
    if cfg.family == "mla":
        nd = (cache["ckv"].shape[0] - params["layers"]["ln1"].shape[0]
              if "dense_layers" in params else 0)
        if nd:
            xs_dense = (params["dense_layers"], cache["ckv"][:nd],
                        cache["kr"][:nd])
        xs = (params["layers"], cache["ckv"][nd:], cache["kr"][nd:])
    else:
        xs = (params["layers"], cache["k"], cache["v"])

    def scan_fn(x, lp_and_cache):
        lp, c0, c1 = lp_and_cache
        x = _dist.shard_activation(x)
        x, (n0, n1) = _layer_decode(lp, x, positions, cfg, (c0, c1), kv_len)
        return x, (n0, n1)

    new_caches = []
    for _ in range(cfg.scan_repeats):   # >1 only in dry-run accounting mode
        new_caches = []
        if xs_dense is not None:
            x, nc = jax.lax.scan(scan_fn, x, xs_dense)
            new_caches.append(nc)
        x, nc = jax.lax.scan(scan_fn, x, xs)
        new_caches.append(nc)

    x = rms_norm(x, params["ln_f"], cfg.norm_eps)
    unembed = params.get("unembed", params["embed"])
    logits = jnp.einsum("bd,vd->bv", x, unembed,
                        preferred_element_type=jnp.float32)
    if len(new_caches) == 2:
        n0 = jax.tree.map(lambda a, c: jnp.concatenate([a, c], 0),
                          new_caches[0], new_caches[1])
    else:
        n0 = new_caches[0]
    if cfg.family == "mla":
        new_cache = {"ckv": n0[0], "kr": n0[1], "len": kv_len + 1}
    else:
        new_cache = {"k": n0[0], "v": n0[1], "len": kv_len + 1}
    return logits, new_cache
