"""RecurrentGemma / Griffin: RG-LRU recurrent blocks + local attention, 2:1.

The linear recurrence h_t = a_t * h_{t-1} + b_t runs as a
``jax.lax.associative_scan`` (log-depth — this is what makes ``long_500k``
tractable) and as one fused step at decode.  The (R, R, A) layer pattern is
scanned per super-block, so compile size is one super-block body; the
trailing partial super-block (38 = 12*3 + 2 in the 9B config) is a second,
smaller scan.  Decode keeps a ring-buffer window cache for the local
attention layers — memory is O(window + lru_width), independent of context.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.dist import partition as _dist

from .common import (
    KeyGen, apply_rope, blockwise_attention, chunked_softmax_xent,
    decode_attention_xla, dense_init, rms_norm,
)
from .config import ArchConfig
from .transformer import _init_attention, _init_dense_ffn, _stack, ffn_dense

_C_RGLRU = 8.0


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------
def _init_recurrent(kg: KeyGen, cfg: ArchConfig, dtype):
    d = cfg.d_model
    w = cfg.rglru.lru_width or d
    cw = cfg.rglru.conv_width
    return {
        "w_in": dense_init(kg(), (d, w), dtype=dtype),
        "w_gate_branch": dense_init(kg(), (d, w), dtype=dtype),
        "conv_w": dense_init(kg(), (cw, w), dtype=dtype),
        "conv_b": jnp.zeros((w,), dtype),
        "wi_gate": dense_init(kg(), (w, w), dtype=dtype),
        "wr_gate": dense_init(kg(), (w, w), dtype=dtype),
        "lambda_p": jnp.full((w,), 2.0, jnp.float32),
        "w_out": dense_init(kg(), (w, d), dtype=dtype),
    }


def _init_block(kg: KeyGen, cfg: ArchConfig, kind: str, dtype):
    p = {
        "ln1": jnp.zeros((cfg.d_model,), dtype),
        "ln2": jnp.zeros((cfg.d_model,), dtype),
        "ffn": _init_dense_ffn(kg, cfg.d_model, cfg.d_ff, dtype),
    }
    if kind == "attn":
        p["attn"] = _init_attention(kg, cfg, dtype)
    else:
        p["rec"] = _init_recurrent(kg, cfg, dtype)
    return p


def init_params(cfg: ArchConfig, key):
    dtype = jnp.dtype(cfg.param_dtype)
    kg = KeyGen(key)
    pat = cfg.rglru.pattern
    n_super, n_tail = divmod(cfg.n_layers, len(pat))
    params = {
        "embed": dense_init(kg(), (cfg.vocab_padded, cfg.d_model),
                            in_axis=1, dtype=dtype),
        "ln_f": jnp.zeros((cfg.d_model,), dtype),
        "super": _stack([
            {f"{kind}_{i}": _init_block(kg, cfg, kind, dtype)
             for i, kind in enumerate(pat)} for _ in range(n_super)]),
    }
    if not cfg.tie_embeddings:
        params["unembed"] = dense_init(kg(), (cfg.vocab_padded, cfg.d_model),
                                       in_axis=1, dtype=dtype)
    if n_tail:
        params["tail"] = _stack([
            {f"{pat[i]}_{i}": _init_block(kg, cfg, pat[i], dtype)
             for i in range(n_tail)}])
    return params


# ---------------------------------------------------------------------------
# RG-LRU primitive
# ---------------------------------------------------------------------------
def _rglru_coeffs(p, x):
    xf = x.astype(jnp.float32)
    i_gate = jax.nn.sigmoid(jnp.einsum(
        "...w,wk->...k", x, p["wi_gate"]).astype(jnp.float32))
    r_gate = jax.nn.sigmoid(jnp.einsum(
        "...w,wk->...k", x, p["wr_gate"]).astype(jnp.float32))
    log_a = -_C_RGLRU * jax.nn.softplus(p["lambda_p"]) * r_gate
    a = jnp.exp(log_a)
    norm = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12))
    return a, norm * (i_gate * xf)


def rglru_seq(p, x, h0=None):
    """x: (B, S, W) -> (y (B,S,W), final state (B,W) f32)."""
    a, b = _rglru_coeffs(p, x)
    if h0 is not None:
        b = b.at[:, 0].add(a[:, 0] * h0)

    def op(lhs, rhs):
        a1, b1 = lhs
        a2, b2 = rhs
        return a1 * a2, a2 * b1 + b2

    _, h = jax.lax.associative_scan(op, (a, b), axis=1)
    return h.astype(x.dtype), h[:, -1]


def rglru_step(p, x, h_prev):
    a, b = _rglru_coeffs(p, x)
    h = a * h_prev + b
    return h.astype(x.dtype), h


def _conv1d_seq(p, x, conv_width: int):
    out = x * p["conv_w"][-1]
    for i in range(1, conv_width):
        shifted = jnp.pad(x, ((0, 0), (i, 0), (0, 0)))[:, : x.shape[1], :]
        out = out + shifted * p["conv_w"][conv_width - 1 - i]
    return out + p["conv_b"]


def _conv1d_step(p, x, buf):
    window = jnp.concatenate([buf, x[:, None, :]], axis=1)  # (B, cw, W)
    out = jnp.einsum("bcw,cw->bw", window, p["conv_w"]) + p["conv_b"]
    return out, window[:, 1:, :]


def recurrent_block_seq(p, x):
    """Returns (y, (final_state f32, conv_tail))."""
    gate = jax.nn.gelu(jnp.einsum("bsd,dw->bsw", x, p["w_gate_branch"])
                       .astype(jnp.float32)).astype(x.dtype)
    h_in = jnp.einsum("bsd,dw->bsw", x, p["w_in"])
    h = _conv1d_seq(p, h_in, p["conv_w"].shape[0])
    h, final_state = rglru_seq(p, h)
    conv_tail = h_in[:, -(p["conv_w"].shape[0] - 1):, :]
    return jnp.einsum("bsw,wd->bsd", h * gate, p["w_out"]), \
        (final_state, conv_tail)


def recurrent_block_step(p, x, state, conv_buf):
    gate = jax.nn.gelu((x @ p["w_gate_branch"]).astype(jnp.float32)
                       ).astype(x.dtype)
    h = x @ p["w_in"]
    h, conv_buf = _conv1d_step(p, h, conv_buf)
    h, state = rglru_step(p, h, state)
    return (h * gate) @ p["w_out"], state, conv_buf


# ---------------------------------------------------------------------------
# Sequence blocks
# ---------------------------------------------------------------------------
def _attn_seq(bp, x, positions, cfg: ArchConfig):
    b, s, _ = x.shape
    hh, hkv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim_
    p = bp["attn"]
    q = jnp.einsum("bsd,dk->bsk", x, p["wq"]).reshape(b, s, hh, dh)
    k = jnp.einsum("bsd,dk->bsk", x, p["wk"]).reshape(b, s, hkv, dh)
    v = jnp.einsum("bsd,dk->bsk", x, p["wv"]).reshape(b, s, hkv, dh)
    q, k, v = (t.transpose(0, 2, 1, 3) for t in (q, k, v))
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    y = blockwise_attention(q, k, v, causal=True, window=cfg.rglru.window,
                            q_chunk=cfg.attn_q_chunk,
                            k_chunk=cfg.attn_k_chunk,
                            unroll=cfg.exact_count)
    y = y.transpose(0, 2, 1, 3).reshape(b, s, hh * dh)
    return jnp.einsum("bsk,kd->bsd", y, p["wo"]), (k, v)


def _block_seq(bp, kind, x, positions, cfg: ArchConfig):
    h = rms_norm(x, bp["ln1"], cfg.norm_eps)
    if kind == "attn":
        y, cache_out = _attn_seq(bp, h, positions, cfg)
    else:
        y, cache_out = recurrent_block_seq(bp["rec"], h)
    x = x + y
    h = rms_norm(x, bp["ln2"], cfg.norm_eps)
    return x + ffn_dense(bp["ffn"], h), cache_out


def _super_body(sp, x, positions, cfg: ArchConfig, pat):
    caches = []
    for i, kind in enumerate(pat):
        key = f"{kind}_{i}"
        if key not in sp:
            continue
        x, c = _block_seq(sp[key], kind, x, positions, cfg)
        caches.append((kind, c))
    return x, caches


def _scan_stack(params_stack, x, positions, cfg, pat, remat):
    def body(x, sp):
        x = _dist.shard_activation(x)
        x, caches = _super_body(sp, x, positions, cfg, pat)
        # split attention / recurrent cache outputs into homogeneous tuples
        attn_c = tuple(c for kd, c in caches if kd == "attn")
        rec_c = tuple(c for kd, c in caches if kd == "rglru")
        return x, (attn_c, rec_c)

    if remat:
        body = jax.checkpoint(body)
    return jax.lax.scan(body, x, params_stack)


def forward(params, cfg: ArchConfig, batch, collect_cache: bool = False):
    x = params["embed"][batch["tokens"]]
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    pat = cfg.rglru.pattern
    for _ in range(cfg.scan_repeats):   # >1 only in dry-run accounting mode
        x, caches = _scan_stack(params["super"], x, positions, cfg, pat,
                                cfg.remat)
        tail_caches = None
        if "tail" in params:
            n_tail = cfg.n_layers % len(pat)
            x, tail_caches = _scan_stack(params["tail"], x, positions, cfg,
                                         pat[:n_tail], cfg.remat)
    x = rms_norm(x, params["ln_f"], cfg.norm_eps)
    if collect_cache:
        return x, (caches, tail_caches)
    return x


def loss_fn(params, cfg: ArchConfig, batch):
    hidden = forward(params, cfg, batch)
    b, s, d = hidden.shape
    unembed = params.get("unembed", params["embed"])
    nll, denom = chunked_softmax_xent(
        hidden.reshape(b * s, d), unembed, batch["labels"].reshape(b * s),
        None, chunk=cfg.loss_chunk, unroll=cfg.exact_count)
    loss = nll / jnp.maximum(denom, 1.0)
    return loss, {"nll": loss}


# ---------------------------------------------------------------------------
# Serving
# ---------------------------------------------------------------------------
def _counts(cfg: ArchConfig):
    pat = cfg.rglru.pattern
    n_super, n_tail = divmod(cfg.n_layers, len(pat))
    apb = sum(1 for kd in pat if kd == "attn")
    rpb = len(pat) - apb
    tail_a = sum(1 for kd in pat[:n_tail] if kd == "attn")
    tail_r = n_tail - tail_a
    return n_super, apb, rpb, tail_a, tail_r


def init_cache(cfg: ArchConfig, batch_size: int, max_seq: int,
               dtype=jnp.bfloat16):
    n_super, apb, rpb, tail_a, tail_r = _counts(cfg)
    w = cfg.rglru.lru_width or cfg.d_model
    window = min(cfg.rglru.window, max_seq)
    dh, hkv = cfg.head_dim_, cfg.n_kv_heads
    return {
        "k": jnp.zeros((n_super * apb + tail_a, batch_size, window, hkv, dh),
                       dtype),
        "v": jnp.zeros((n_super * apb + tail_a, batch_size, window, hkv, dh),
                       dtype),
        "state": jnp.zeros((n_super * rpb + tail_r, batch_size, w),
                           jnp.float32),
        "conv": jnp.zeros((n_super * rpb + tail_r, batch_size,
                           cfg.rglru.conv_width - 1, w), dtype),
        "len": jnp.zeros((batch_size,), jnp.int32),
    }


def prefill(params, cfg: ArchConfig, batch, max_seq: int):
    hidden, (caches, tail_caches) = forward(params, cfg, batch,
                                            collect_cache=True)
    b, s, d = hidden.shape
    unembed = params.get("unembed", params["embed"])
    logits = jnp.einsum("bd,vd->bv", hidden[:, -1], unembed,
                        preferred_element_type=jnp.float32)
    window = min(cfg.rglru.window, max_seq)

    def ring(k):  # (N, B, Hkv, S, dh) -> windowed ring layout (N,B,win,Hkv,dh)
        if s < window:  # slots [0, s) filled in order; next write at s
            kw = jnp.pad(k, ((0, 0),) * 3 + ((0, window - s), (0, 0)))
            return kw.transpose(0, 1, 3, 2, 4)
        kw = k[:, :, :, -window:, :].transpose(0, 1, 3, 2, 4)
        return jnp.roll(kw, s % window, axis=2)

    def flat(groups):
        """(n_super, per_block, ...) scan output -> (n_super*per_block, ...)"""
        if not groups:
            return None
        stacked = jnp.stack(groups, axis=1) if isinstance(groups, tuple) \
            else groups
        return stacked.reshape((-1,) + stacked.shape[2:])

    attn_c, rec_c = caches
    parts = {"k": [], "v": [], "state": [], "conv": []}
    if attn_c:
        ks = jnp.stack([c[0] for c in attn_c], axis=1)  # (S?,apb,B,hkv,s,dh)
        vs = jnp.stack([c[1] for c in attn_c], axis=1)
        parts["k"].append(ring(ks.reshape((-1,) + ks.shape[2:])))
        parts["v"].append(ring(vs.reshape((-1,) + vs.shape[2:])))
    if rec_c:
        st = jnp.stack([c[0] for c in rec_c], axis=1)
        cv = jnp.stack([c[1] for c in rec_c], axis=1)
        parts["state"].append(st.reshape((-1,) + st.shape[2:]))
        parts["conv"].append(cv.reshape((-1,) + cv.shape[2:]))
    if tail_caches is not None:
        t_attn, t_rec = tail_caches
        if t_attn:
            ks = jnp.stack([c[0] for c in t_attn], axis=1)
            vs = jnp.stack([c[1] for c in t_attn], axis=1)
            parts["k"].append(ring(ks.reshape((-1,) + ks.shape[2:])))
            parts["v"].append(ring(vs.reshape((-1,) + vs.shape[2:])))
        if t_rec:
            st = jnp.stack([c[0] for c in t_rec], axis=1)
            cv = jnp.stack([c[1] for c in t_rec], axis=1)
            parts["state"].append(st.reshape((-1,) + st.shape[2:]))
            parts["conv"].append(cv.reshape((-1,) + cv.shape[2:]))
    cache = {
        "k": jnp.concatenate(parts["k"], 0),
        "v": jnp.concatenate(parts["v"], 0),
        "state": jnp.concatenate(parts["state"], 0),
        "conv": jnp.concatenate(parts["conv"], 0),
        "len": jnp.full((b,), s, jnp.int32),
    }
    return logits, cache


def _decode_block(bp, kind, x, cache_rows, kv_len, window, cfg: ArchConfig):
    h = rms_norm(x, bp["ln1"], cfg.norm_eps)
    if kind == "attn":
        ck, cv = cache_rows
        p = bp["attn"]
        b = x.shape[0]
        hh, hkv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim_
        slot = kv_len % window
        q = (h @ p["wq"]).reshape(b, hh, dh)
        k = (h @ p["wk"]).reshape(b, hkv, dh)
        v = (h @ p["wv"]).reshape(b, hkv, dh)
        q = apply_rope(q[:, :, None, :], kv_len[:, None],
                       cfg.rope_theta)[:, :, 0, :]
        k = apply_rope(k[:, :, None, :], kv_len[:, None],
                       cfg.rope_theta)[:, :, 0, :]
        upd = jax.vmap(lambda c, n, i: jax.lax.dynamic_update_slice_in_dim(
            c, n[None], i, axis=0))
        ck, cv = upd(ck, k, slot), upd(cv, v, slot)
        n_valid = jnp.minimum(kv_len + 1, window)
        y = decode_attention_xla(q, ck.transpose(0, 2, 1, 3),
                                 cv.transpose(0, 2, 1, 3), n_valid)
        y = (y.reshape(b, hh * dh)) @ p["wo"]
        new_rows = (ck, cv)
    else:
        st, cb = cache_rows
        y, st, cb = recurrent_block_step(bp["rec"], h, st, cb)
        new_rows = (st, cb)
    x = x + y
    h = rms_norm(x, bp["ln2"], cfg.norm_eps)
    return x + ffn_dense(bp["ffn"], h), new_rows


def decode_step(params, cfg: ArchConfig, cache, tokens, positions=None):
    x = params["embed"][tokens]
    kv_len = cache["len"]
    pat = cfg.rglru.pattern
    n_super, apb, rpb, tail_a, tail_r = _counts(cfg)
    window = cache["k"].shape[2]

    def regroup(arr, n_blocks, per):
        return arr[: n_blocks * per].reshape((n_blocks, per) + arr.shape[1:])

    xs = (params["super"],
          regroup(cache["k"], n_super, apb), regroup(cache["v"], n_super, apb),
          regroup(cache["state"], n_super, rpb),
          regroup(cache["conv"], n_super, rpb))

    def body(x, xs_sb):
        sp, ck, cv, st, cb = xs_sb
        x = _dist.shard_activation(x)
        ai = ri = 0
        new_k, new_v, new_s, new_c = [], [], [], []
        for i, kind in enumerate(pat):
            rows = ((ck[ai], cv[ai]) if kind == "attn"
                    else (st[ri], cb[ri]))
            x2, new_rows = _decode_block(sp[f"{kind}_{i}"], kind, x, rows,
                                         kv_len, window, cfg)
            x = x2
            if kind == "attn":
                new_k.append(new_rows[0])
                new_v.append(new_rows[1])
                ai += 1
            else:
                new_s.append(new_rows[0])
                new_c.append(new_rows[1])
                ri += 1
        return x, (jnp.stack(new_k), jnp.stack(new_v),
                   jnp.stack(new_s), jnp.stack(new_c))

    for _ in range(cfg.scan_repeats):   # >1 only in dry-run accounting mode
        x, (nk, nv, ns, nc) = jax.lax.scan(body, x, xs)
    nk = nk.reshape((-1,) + nk.shape[2:])
    nv = nv.reshape((-1,) + nv.shape[2:])
    ns = ns.reshape((-1,) + ns.shape[2:])
    nc = nc.reshape((-1,) + nc.shape[2:])

    if "tail" in params:
        tp = jax.tree.map(lambda a: a[0], params["tail"])
        ai, ri = n_super * apb, n_super * rpb
        tail_k, tail_v, tail_s, tail_c = [], [], [], []
        n_tail = cfg.n_layers % len(pat)
        for i in range(n_tail):
            kind = pat[i]
            rows = ((cache["k"][ai], cache["v"][ai]) if kind == "attn"
                    else (cache["state"][ri], cache["conv"][ri]))
            x, new_rows = _decode_block(tp[f"{kind}_{i}"], kind, x, rows,
                                        kv_len, window, cfg)
            if kind == "attn":
                tail_k.append(new_rows[0])
                tail_v.append(new_rows[1])
                ai += 1
            else:
                tail_s.append(new_rows[0])
                tail_c.append(new_rows[1])
                ri += 1
        if tail_k:
            nk = jnp.concatenate([nk, jnp.stack(tail_k)], 0)
            nv = jnp.concatenate([nv, jnp.stack(tail_v)], 0)
        if tail_s:
            ns = jnp.concatenate([ns, jnp.stack(tail_s)], 0)
            nc = jnp.concatenate([nc, jnp.stack(tail_c)], 0)

    x = rms_norm(x, params["ln_f"], cfg.norm_eps)
    unembed = params.get("unembed", params["embed"])
    logits = jnp.einsum("bd,vd->bv", x, unembed,
                        preferred_element_type=jnp.float32)
    return logits, {"k": nk, "v": nv, "state": ns, "conv": nc,
                    "len": kv_len + 1}
