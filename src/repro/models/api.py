"""Family-dispatching model API: one interface over all assigned archs.

    init_params(cfg, key)                  -> params pytree
    loss_fn(params, cfg, batch)            -> (scalar loss, metrics)
    prefill(params, cfg, batch, max_seq)   -> (last-token logits, cache)
    decode_step(params, cfg, cache, toks)  -> (logits, new cache)
    init_cache(cfg, batch, max_seq)        -> zeroed cache pytree
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import rglru, ssm, transformer
from .config import ArchConfig

_FAMS = {
    "dense": transformer, "moe": transformer, "mla": transformer,
    "rglru": rglru, "ssm": ssm,
}


def _mod(cfg: ArchConfig):
    return _FAMS[cfg.family]


def init_params(cfg: ArchConfig, key):
    return _mod(cfg).init_params(cfg, key)


def abstract_params(cfg: ArchConfig):
    """Parameter shapes without allocation (for the dry-run)."""
    return jax.eval_shape(
        lambda k: init_params(cfg, k), jax.random.key(0))


def loss_fn(params, cfg: ArchConfig, batch):
    return _mod(cfg).loss_fn(params, cfg, batch)


def prefill(params, cfg: ArchConfig, batch, max_seq: int):
    return _mod(cfg).prefill(params, cfg, batch, max_seq)


def decode_step(params, cfg: ArchConfig, cache, tokens, positions=None):
    if cfg.encoder_only:
        raise ValueError(f"{cfg.name} is encoder-only: no decode step")
    return _mod(cfg).decode_step(params, cfg, cache, tokens, positions)


def init_cache(cfg: ArchConfig, batch_size: int, max_seq: int,
               dtype=jnp.bfloat16):
    return _mod(cfg).init_cache(cfg, batch_size, max_seq, dtype)


def make_batch(cfg: ArchConfig, batch_size: int, seq_len: int, key=None):
    """A synthetic batch with the right structure for `cfg` (smoke tests)."""
    key = key if key is not None else jax.random.key(0)
    k1, k2, k3 = jax.random.split(key, 3)
    batch = {}
    if cfg.inputs == "embeddings":
        batch["embeds"] = (jax.random.normal(
            k1, (batch_size, seq_len, cfg.d_model), jnp.float32) * 0.02
        ).astype(jnp.dtype(cfg.param_dtype))
    else:
        batch["tokens"] = jax.random.randint(
            k1, (batch_size, seq_len), 0, cfg.vocab, jnp.int32)
    batch["labels"] = jax.random.randint(
        k2, (batch_size, seq_len), 0, cfg.vocab, jnp.int32)
    if cfg.mrope:
        pos = jnp.broadcast_to(jnp.arange(seq_len, dtype=jnp.int32),
                               (batch_size, seq_len))
        batch["positions"] = jnp.stack([pos, pos * 0, pos * 0], 0)
    return batch
