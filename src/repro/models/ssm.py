"""Mamba-2 (SSD, state-space duality) — attention-free LM family.

Train/prefill use the chunked SSD algorithm in pure JAX (intra-chunk
quadratic masked-decay matmuls + a small carried inter-chunk state), the
same decomposition the Pallas kernel in ``repro.kernels.ssd`` implements
for real TPUs.  Decode is a single fused recurrence step — O(1) per token,
which is why this family runs the ``long_500k`` cell.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.dist import partition as _dist

from .common import KeyGen, chunked_softmax_xent, dense_init, rms_norm
from .config import ArchConfig


def _dims(cfg: ArchConfig):
    s = cfg.ssm
    d_in = s.expand * cfg.d_model
    n_heads = d_in // s.head_dim
    conv_ch = d_in + 2 * s.d_state
    return d_in, n_heads, conv_ch


def _init_layer(kg: KeyGen, cfg: ArchConfig, dtype):
    d = cfg.d_model
    s = cfg.ssm
    d_in, h, conv_ch = _dims(cfg)
    return {
        "ln": jnp.zeros((d,), dtype),
        "w_in": dense_init(kg(), (d, 2 * d_in + 2 * s.d_state + h),
                           dtype=dtype),
        "conv_w": dense_init(kg(), (s.d_conv, conv_ch), dtype=dtype),
        "conv_b": jnp.zeros((conv_ch,), dtype),
        "dt_bias": jnp.zeros((h,), jnp.float32),
        "a_log": jnp.log(jnp.linspace(1.0, 16.0, h).astype(jnp.float32)),
        "d_skip": jnp.ones((h,), jnp.float32),
        "gn": jnp.zeros((d_in,), dtype),      # gated RMSNorm scale
        "w_out": dense_init(kg(), (d_in, d), dtype=dtype),
    }


def init_params(cfg: ArchConfig, key):
    dtype = jnp.dtype(cfg.param_dtype)
    kg = KeyGen(key)
    from .transformer import _stack
    params = {
        "embed": dense_init(kg(), (cfg.vocab_padded, cfg.d_model),
                            in_axis=1, dtype=dtype),
        "ln_f": jnp.zeros((cfg.d_model,), dtype),
        "layers": _stack([_init_layer(kg, cfg, dtype)
                          for _ in range(cfg.n_layers)]),
    }
    if not cfg.tie_embeddings:
        params["unembed"] = dense_init(kg(), (cfg.vocab_padded, cfg.d_model),
                                       in_axis=1, dtype=dtype)
    return params


# ---------------------------------------------------------------------------
# Chunked SSD (jnp)
# ---------------------------------------------------------------------------
def ssd_chunked(x, dt, a, b, c, chunk: int, state0=None,
                unroll: bool = False):
    """x: (B,S,H,P); dt: (B,S,H) (already softplus'd); a: (H,) negative;
    b, c: (B,S,N).  Returns (y (B,S,H,P), final_state (B,H,N,P) f32)."""
    import math
    bsz, s, h, p = x.shape
    n = b.shape[-1]
    chunk = math.gcd(min(chunk, s), s)   # largest dividing chunk
    nc = s // chunk

    xf = x.astype(jnp.float32) * dt.astype(jnp.float32)[..., None]
    la = dt.astype(jnp.float32) * a[None, None, :]      # (B,S,H) log-decay
    xs = xf.reshape(bsz, nc, chunk, h, p)
    las = la.reshape(bsz, nc, chunk, h)
    bs = b.astype(jnp.float32).reshape(bsz, nc, chunk, n)
    cs = c.astype(jnp.float32).reshape(bsz, nc, chunk, n)

    ii = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    tri = jj <= ii

    def step(state, inp):
        xc, lac, bc, cc = inp                # (B,L,H,P),(B,L,H),(B,L,N)x2
        cum = jnp.cumsum(lac, axis=1)        # (B,L,H) inclusive
        seg = cum[:, :, None, :] - cum[:, None, :, :]      # (B,L,L,H)
        # mask BEFORE exp: the upper triangle is exp(+large) = inf, and
        # inf * 0 poisons the backward pass with NaNs
        seg = jnp.where(tri[None, :, :, None], seg, -jnp.inf)
        lmat = jnp.exp(seg)
        # (B,L,L,H) is the fat intermediate (observed 26.8 GiB/device on
        # mamba2 train_4k at chunk=256): keep heads on the model axis
        lmat = _dist.shard_named(lmat, ("D", "-", "-", "T"))
        scores = jnp.einsum("bln,bmn->blm", cc, bc)        # (B,L,L) shared
        y = jnp.einsum("blm,blmh,bmhp->blhp", scores, lmat, xc)
        # inter-chunk: state contribution
        y = y + jnp.exp(cum)[..., None] * jnp.einsum(
            "bln,bhnp->blhp", cc, state)
        # state update
        decay_all = jnp.exp(cum[:, -1])                    # (B,H)
        w = jnp.exp(cum[:, -1:, :] - cum)                  # (B,L,H)
        state = (state * decay_all[..., None, None]
                 + jnp.einsum("bln,blh,blhp->bhnp", bc, w, xc))
        return state, y

    state0 = (jnp.zeros((bsz, h, n, p), jnp.float32) if state0 is None
              else state0)
    xs_t = (jnp.moveaxis(xs, 1, 0), jnp.moveaxis(las, 1, 0),
            jnp.moveaxis(bs, 1, 0), jnp.moveaxis(cs, 1, 0))
    final, ys = jax.lax.scan(step, state0, xs_t,
                             unroll=nc if unroll else 1)
    y = jnp.moveaxis(ys, 0, 1).reshape(bsz, s, h, p)
    return y.astype(x.dtype), final


def _conv1d_seq(w, bias, x):
    """Causal depthwise conv.  x: (B, S, C); w: (cw, C)."""
    out = x * w[-1]
    for i in range(1, w.shape[0]):
        shifted = jnp.pad(x, ((0, 0), (i, 0), (0, 0)))[:, : x.shape[1], :]
        out = out + shifted * w[w.shape[0] - 1 - i]
    return out + bias


def _layer_seq(lp, x, cfg: ArchConfig):
    """Returns (x_out, (final_state, conv_tail))."""
    s_cfg = cfg.ssm
    d_in, h, conv_ch = _dims(cfg)
    n = s_cfg.d_state
    hidden = rms_norm(x, lp["ln"], cfg.norm_eps)
    proj = jnp.einsum("bsd,dk->bsk", hidden, lp["w_in"])
    z, xbc, dt_raw = jnp.split(proj, [d_in, d_in + conv_ch], axis=-1)
    conv_tail = xbc[:, -(s_cfg.d_conv - 1):, :]
    xbc = jax.nn.silu(_conv1d_seq(lp["conv_w"], lp["conv_b"], xbc)
                      .astype(jnp.float32)).astype(x.dtype)
    xs, b, c = jnp.split(xbc, [d_in, d_in + n], axis=-1)
    bsz, s, _ = x.shape
    xs = xs.reshape(bsz, s, h, s_cfg.head_dim)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + lp["dt_bias"])
    a = -jnp.exp(lp["a_log"])
    y, final_state = ssd_chunked(xs, dt, a, b, c, s_cfg.chunk,
                                 unroll=cfg.exact_count)
    y = y + (xs.astype(jnp.float32) * lp["d_skip"][None, None, :, None]
             ).astype(y.dtype)
    y = y.reshape(bsz, s, d_in)
    y = rms_norm(y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype),
                 lp["gn"], cfg.norm_eps)
    return x + jnp.einsum("bsk,kd->bsd", y, lp["w_out"]), \
        (final_state, conv_tail)


def forward(params, cfg: ArchConfig, batch, collect_cache: bool = False):
    x = params["embed"][batch["tokens"]]

    def body(x, lp):
        return _layer_seq(lp, _dist.shard_activation(x), cfg)

    if cfg.remat:
        body = jax.checkpoint(body)
    for _ in range(cfg.scan_repeats):   # >1 only in dry-run accounting mode
        x, caches = jax.lax.scan(body, x, params["layers"])
    x = rms_norm(x, params["ln_f"], cfg.norm_eps)
    if collect_cache:
        return x, caches
    return x


def loss_fn(params, cfg: ArchConfig, batch):
    hidden = forward(params, cfg, batch)
    b, s, d = hidden.shape
    unembed = params.get("unembed", params["embed"])
    nll, denom = chunked_softmax_xent(
        hidden.reshape(b * s, d), unembed, batch["labels"].reshape(b * s),
        None, chunk=cfg.loss_chunk, unroll=cfg.exact_count)
    loss = nll / jnp.maximum(denom, 1.0)
    return loss, {"nll": loss}


# ---------------------------------------------------------------------------
# Serving — O(1) per-token state recurrence
# ---------------------------------------------------------------------------
def init_cache(cfg: ArchConfig, batch_size: int, max_seq: int,
               dtype=jnp.bfloat16):
    s_cfg = cfg.ssm
    d_in, h, conv_ch = _dims(cfg)
    return {
        "state": jnp.zeros((cfg.n_layers, batch_size, h, s_cfg.d_state,
                            s_cfg.head_dim), jnp.float32),
        "conv": jnp.zeros((cfg.n_layers, batch_size, s_cfg.d_conv - 1,
                           conv_ch), dtype),
        "len": jnp.zeros((batch_size,), jnp.int32),
    }


def prefill(params, cfg: ArchConfig, batch, max_seq: int):
    hidden, (states, conv_tails) = forward(params, cfg, batch,
                                           collect_cache=True)
    b = hidden.shape[0]
    unembed = params.get("unembed", params["embed"])
    logits = jnp.einsum("bd,vd->bv", hidden[:, -1], unembed,
                        preferred_element_type=jnp.float32)
    cache = {"state": states, "conv": conv_tails,
             "len": jnp.full((b,), hidden.shape[1], jnp.int32)}
    return logits, cache


def _layer_step(lp, x, state, conv_buf, cfg: ArchConfig):
    s_cfg = cfg.ssm
    d_in, h, conv_ch = _dims(cfg)
    n = s_cfg.d_state
    hidden = rms_norm(x, lp["ln"], cfg.norm_eps)
    proj = hidden @ lp["w_in"]
    z, xbc, dt_raw = jnp.split(proj, [d_in, d_in + conv_ch], axis=-1)
    window = jnp.concatenate([conv_buf, xbc[:, None, :]], axis=1)
    conv_out = jnp.einsum("bcw,cw->bw", window, lp["conv_w"]) + lp["conv_b"]
    new_conv = window[:, 1:, :]
    xbc = jax.nn.silu(conv_out.astype(jnp.float32)).astype(x.dtype)
    xs, b, c = jnp.split(xbc, [d_in, d_in + n], axis=-1)
    bsz = x.shape[0]
    xs = xs.reshape(bsz, h, s_cfg.head_dim)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + lp["dt_bias"])  # (B,H)
    a = -jnp.exp(lp["a_log"])
    decay = jnp.exp(dt * a[None, :])                                  # (B,H)
    dbx = jnp.einsum("bn,bhp->bhnp", b.astype(jnp.float32),
                     xs.astype(jnp.float32) * dt[..., None])
    state = state * decay[..., None, None] + dbx
    y = jnp.einsum("bn,bhnp->bhp", c.astype(jnp.float32), state)
    y = y + xs.astype(jnp.float32) * lp["d_skip"][None, :, None]
    y = y.reshape(bsz, d_in).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype),
                 lp["gn"], cfg.norm_eps)
    return x + y @ lp["w_out"], state, new_conv


def decode_step(params, cfg: ArchConfig, cache, tokens, positions=None):
    x = params["embed"][tokens]

    def body(x, xs):
        lp, st, cb = xs
        x, st, cb = _layer_step(lp, _dist.shard_activation(x), st, cb, cfg)
        return x, (st, cb)

    for _ in range(cfg.scan_repeats):   # >1 only in dry-run accounting mode
        x, (states, convs) = jax.lax.scan(
            body, x, (params["layers"], cache["state"], cache["conv"]))
    x = rms_norm(x, params["ln_f"], cfg.norm_eps)
    unembed = params.get("unembed", params["embed"])
    logits = jnp.einsum("bd,vd->bv", x, unembed,
                        preferred_element_type=jnp.float32)
    return logits, {"state": states, "conv": convs, "len": cache["len"] + 1}
