"""Shared model machinery: norms, rotary embeddings, blockwise attention,
chunked cross-entropy.  Pure JAX/XLA — the Pallas kernels in
``repro.kernels`` are drop-in replacements for the hot paths on real TPUs;
the XLA formulations here are what the multi-pod dry-run lowers.
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np


def rms_norm(x, scale, eps: float = 1e-6):
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    return ((x * jax.lax.rsqrt(var + eps)) * (1.0 + scale.astype(jnp.float32))
            ).astype(dtype)


# ---------------------------------------------------------------------------
# Rotary embeddings (RoPE + Qwen2-VL M-RoPE)
# ---------------------------------------------------------------------------
def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x, positions, theta: float):
    """x: (B, H, S, D); positions: (B, S) int32."""
    freqs = rope_freqs(x.shape[-1], theta)                       # (D/2,)
    angles = positions[:, None, :, None].astype(jnp.float32) * freqs
    cos, sin = jnp.cos(angles), jnp.sin(angles)                  # (B,1,S,D/2)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(x, positions, theta: float, sections=(1, 2, 2)):
    """Qwen2-VL multimodal RoPE: positions (3, B, S) for (t, h, w); the D/2
    frequency pairs are split between the three components in `sections`
    proportion (16/24/24 in the released model ~ 1:1.5:1.5)."""
    d2 = x.shape[-1] // 2
    total = sum(sections)
    splits = [d2 * s // total for s in sections]
    splits[-1] = d2 - sum(splits[:-1])
    freqs = rope_freqs(x.shape[-1], theta)                       # (D/2,)
    comp = jnp.repeat(
        jnp.arange(3), jnp.asarray(splits), total_repeat_length=d2)  # (D/2,)
    pos = positions.astype(jnp.float32)                          # (3, B, S)
    # pick the position component per frequency pair
    pos_per_freq = pos[comp]                                     # (D/2, B, S)
    angles = jnp.transpose(pos_per_freq, (1, 2, 0))[:, None] * freqs  # (B,1,S,D/2)
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Blockwise attention (XLA path).
#
# FLOP-exact flash attention: instead of scanning all (q_chunk, k_chunk)
# pairs and masking (which doubles causal HLO FLOPs and poisons the roofline
# compute term), we enumerate only the *visible* chunk pairs statically and
# lax.scan over that list.  Causal gives ~S^2/2, a local window gives O(S).
# ---------------------------------------------------------------------------
def _visible_pairs(nq: int, nk: int, q_chunk: int, k_chunk: int,
                   causal: bool, window: Optional[int], offset: int):
    """Static list of (qi, ki) chunk pairs with any visible element.
    `offset` is the absolute position of query 0 (for cached decode)."""
    pairs = []
    for qi in range(nq):
        q_lo = offset + qi * q_chunk
        q_hi = q_lo + q_chunk - 1
        for ki in range(nk):
            k_lo = ki * k_chunk
            k_hi = k_lo + k_chunk - 1
            if causal and k_lo > q_hi:
                continue
            if window is not None and k_hi <= q_lo - window:
                continue
            pairs.append((qi, ki))
    return pairs


NEG_INF = -1e30


def _split_pairs(pairs, q_chunk, k_chunk, causal, window, q_offset,
                 has_kv_len):
    """Interior blocks need NO positional mask (TPU-flash structure: masking
    only on causal/window boundary blocks).  Keeping the interior scan
    mask-free also stops XLA hoisting a stacked all-pairs mask tensor out of
    the loop (observed as a 10 GiB pred buffer on qwen1.5 train_4k)."""
    full, masked = [], []
    for qi, ki in pairs:
        q_lo = q_offset + qi * q_chunk
        q_hi = q_lo + q_chunk - 1
        k_lo, k_hi = ki * k_chunk, ki * k_chunk + k_chunk - 1
        needs = has_kv_len
        if causal and k_hi > q_lo:
            needs = True
        if window is not None and k_lo <= q_hi - window:
            needs = True
        (masked if needs else full).append((qi, ki))
    return full, masked


def _block_logits_masked(s, qs, ks, q_chunk, k_chunk, scale, causal, window,
                         q_offset, kv_len):
    q_pos = q_offset + qs + jax.lax.broadcasted_iota(
        jnp.int32, (q_chunk, k_chunk), 0)
    k_pos = ks + jax.lax.broadcasted_iota(
        jnp.int32, (q_chunk, k_chunk), 1)
    mask = jnp.ones((q_chunk, k_chunk), bool)
    if causal:
        mask &= k_pos <= q_pos
    if window is not None:
        mask &= k_pos > q_pos - window
    s = jnp.where(mask, s, NEG_INF)
    if kv_len is not None:
        valid = (ks + jnp.arange(k_chunk)[None, :]) < kv_len[:, None]
        s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    return s


@functools.lru_cache(maxsize=None)
def _make_blockwise(b, h, sq, sk, d, dv, q_chunk, k_chunk, scale,
                    causal, window, q_offset, has_kv_len, dtype_name,
                    unroll=False):
    """FLOP-exact flash attention over the statically-visible chunk pairs,
    with a hand-written (flash) VJP: the forward saves only (q, k, v, out,
    m, l) — O(S) residuals — and the backward recomputes each score block,
    exactly like the Pallas/TPU flash kernels do.  Without this, AD of the
    pair-scan stores O(pairs * S) carries and blows per-device HBM.

    Operates on (B, H, S, D) with KV pre-expanded to H query heads (the
    expansion is per-device cheap once H is sharded over 'model'; its
    gather-VJP sums the group gradient back to the KV heads)."""
    nq, nk = sq // q_chunk, sk // k_chunk
    pairs = _visible_pairs(nq, nk, q_chunk, k_chunk, causal, window, q_offset)
    full_pairs, masked_pairs = _split_pairs(
        pairs, q_chunk, k_chunk, causal, window, q_offset, has_kv_len)

    def logits(qc, kc, qs, ks, kv_len, apply_mask):
        s = jnp.einsum("bhqd,bhkd->bhqk", qc, kc,
                       preferred_element_type=jnp.float32) * scale
        if apply_mask:
            s = _block_logits_masked(s, qs, ks, q_chunk, k_chunk, scale,
                                     causal, window, q_offset, kv_len)
        return s

    def fwd_impl(q, k, v, kv_len):
        acc0 = jnp.zeros((b, h, sq, dv), jnp.float32)
        m0 = jnp.full((b, h, sq), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, h, sq), jnp.float32)

        def step(carry, pair, apply_mask):
            acc, m, l = carry
            qs, ks = pair[0] * q_chunk, pair[1] * k_chunk
            qc = jax.lax.dynamic_slice_in_dim(q, qs, q_chunk, axis=2)
            kc = jax.lax.dynamic_slice_in_dim(k, ks, k_chunk, axis=2)
            vc = jax.lax.dynamic_slice_in_dim(v, ks, k_chunk, axis=2)
            s = logits(qc, kc, qs, ks, kv_len, apply_mask)
            m_prev = jax.lax.dynamic_slice_in_dim(m, qs, q_chunk, axis=2)
            l_prev = jax.lax.dynamic_slice_in_dim(l, qs, q_chunk, axis=2)
            acc_prev = jax.lax.dynamic_slice_in_dim(acc, qs, q_chunk, axis=2)
            m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
            p = jnp.where(s <= NEG_INF / 2, 0.0, jnp.exp(s - m_new[..., None]))
            alpha = jnp.where(m_prev <= NEG_INF / 2, 0.0,
                              jnp.exp(m_prev - m_new))
            l_new = l_prev * alpha + jnp.sum(p, axis=-1)
            acc_new = acc_prev * alpha[..., None] + jnp.einsum(
                "bhqk,bhkd->bhqd", p.astype(v.dtype), vc,
                preferred_element_type=jnp.float32)
            return (jax.lax.dynamic_update_slice_in_dim(acc, acc_new, qs, 2),
                    jax.lax.dynamic_update_slice_in_dim(m, m_new, qs, 2),
                    jax.lax.dynamic_update_slice_in_dim(l, l_new, qs, 2)), None

        carry = (acc0, m0, l0)
        for plist, msk in ((full_pairs, False), (masked_pairs, True)):
            if plist:
                carry, _ = jax.lax.scan(
                    functools.partial(step, apply_mask=msk), carry,
                    np.asarray(plist, np.int32),
                    unroll=len(plist) if unroll else 1)
        acc, m, l = carry
        denom = jnp.where(l == 0.0, 1.0, l)
        out = (acc / denom[..., None]).astype(q.dtype)
        return out, (m, l)

    @jax.custom_vjp
    def attn(q, k, v, kv_len):
        return fwd_impl(q, k, v, kv_len)[0]

    def attn_fwd(q, k, v, kv_len):
        out, (m, l) = fwd_impl(q, k, v, kv_len)
        return out, (q, k, v, kv_len, out, m, l)

    def attn_bwd(res, do):
        q, k, v, kv_len, out, m, l = res
        og = out.astype(jnp.float32)
        dog = do.astype(jnp.float32)
        denom = jnp.where(l == 0.0, 1.0, l)
        delta = jnp.sum(og * dog, axis=-1)                     # (B,H,S)
        dq0 = jnp.zeros((b, h, sq, d), jnp.float32)
        dk0 = jnp.zeros(k.shape, jnp.float32)
        dv0 = jnp.zeros(v.shape, jnp.float32)

        def step(carry, pair, apply_mask):
            dq, dk, dv_ = carry
            qs, ks = pair[0] * q_chunk, pair[1] * k_chunk
            qc = jax.lax.dynamic_slice_in_dim(q, qs, q_chunk, axis=2)
            kc = jax.lax.dynamic_slice_in_dim(k, ks, k_chunk, axis=2)
            vc = jax.lax.dynamic_slice_in_dim(v, ks, k_chunk, axis=2)
            mc = jax.lax.dynamic_slice_in_dim(m, qs, q_chunk, axis=2)
            lc = jax.lax.dynamic_slice_in_dim(denom, qs, q_chunk, axis=2)
            dc = jax.lax.dynamic_slice_in_dim(delta, qs, q_chunk, axis=2)
            doc = jax.lax.dynamic_slice_in_dim(dog, qs, q_chunk, axis=2)
            s = logits(qc, kc, qs, ks, kv_len, apply_mask)
            p = jnp.where(s <= NEG_INF / 2, 0.0,
                          jnp.exp(s - mc[..., None])) / lc[..., None]
            dvc = jnp.einsum("bhqk,bhqd->bhkd", p, doc)
            dp = jnp.einsum("bhqd,bhkd->bhqk", doc, vc.astype(jnp.float32))
            ds = p * (dp - dc[..., None]) * scale
            dqc = jnp.einsum("bhqk,bhkd->bhqd", ds, kc.astype(jnp.float32))
            dkc = jnp.einsum("bhqk,bhqd->bhkd", ds, qc.astype(jnp.float32))
            dq = jax.lax.dynamic_update_slice_in_dim(
                dq, jax.lax.dynamic_slice_in_dim(dq, qs, q_chunk, 2) + dqc,
                qs, 2)
            dk = jax.lax.dynamic_update_slice_in_dim(
                dk, jax.lax.dynamic_slice_in_dim(dk, ks, k_chunk, 2) + dkc,
                ks, 2)
            dv_ = jax.lax.dynamic_update_slice_in_dim(
                dv_, jax.lax.dynamic_slice_in_dim(dv_, ks, k_chunk, 2) + dvc,
                ks, 2)
            return (dq, dk, dv_), None

        carry = (dq0, dk0, dv0)
        for plist, msk in ((full_pairs, False), (masked_pairs, True)):
            if plist:
                carry, _ = jax.lax.scan(
                    functools.partial(step, apply_mask=msk), carry,
                    np.asarray(plist, np.int32),
                    unroll=len(plist) if unroll else 1)
        dq, dk, dv_ = carry
        return (dq.astype(q.dtype), dk.astype(k.dtype),
                dv_.astype(v.dtype), None)

    attn.defvjp(attn_fwd, attn_bwd)
    return attn


def blockwise_attention(q, k, v, *, causal: bool = True,
                        window: Optional[int] = None,
                        q_chunk: int = 512, k_chunk: int = 1024,
                        scale: Optional[float] = None,
                        kv_len=None, q_offset: int = 0, unroll: bool = False):
    """q: (B, Hq, Sq, D); k, v: (B, Hkv, Sk, D), Hq % Hkv == 0.

    kv_len: optional (B,) valid KV prefix lengths (cached decode/prefill).
    q_offset: absolute position of q[0] relative to the KV sequence.
    """
    from repro.dist import partition as _dist

    b, hq, sq, d = q.shape
    hkv, sk = k.shape[1], k.shape[2]
    dv = v.shape[-1]                 # may differ from d (MLA)
    g = hq // hkv
    q_chunk = math.gcd(min(q_chunk, sq), sq)   # largest dividing chunk
    k_chunk = math.gcd(min(k_chunk, sk), sk)
    scale = scale if scale is not None else 1.0 / math.sqrt(d)

    # query-head sharding over 'model'; expand KV to query heads so every
    # per-device tensor inside the flash loops carries H/|model| heads (the
    # gather's VJP sums group gradients back onto the KV heads)
    q = _dist.shard_named(q, ("D", "T", "-", "-"))
    if g > 1:
        kv_map = np.arange(hq) // g
        k = k[:, kv_map]
        v = v[:, kv_map]
    k = _dist.shard_named(k, ("D", "T", "-", "-"))
    v = _dist.shard_named(v, ("D", "T", "-", "-"))

    attn = _make_blockwise(b, hq, sq, sk, d, dv, q_chunk, k_chunk,
                           float(scale), causal, window, q_offset,
                           kv_len is not None, str(q.dtype), unroll)
    out = attn(q, k, v, kv_len)
    return _dist.shard_named(out, ("D", "T", "-", "-"))


def decode_attention_xla(q, k, v, kv_len, *, scale=None, window=None):
    """One new token vs. a cache.  q: (B, Hq, D); k, v: (B, Hkv, S, D);
    kv_len: (B,) — the new token sits at position kv_len - 1."""
    b, hq, d = q.shape
    hkv, s = k.shape[1], k.shape[2]
    g = hq // hkv
    scale = scale if scale is not None else 1.0 / math.sqrt(d)
    qf = q.reshape(b, hkv, g, d) * scale
    logits = jnp.einsum("bhgd,bhtd->bhgt", qf, k,
                        preferred_element_type=jnp.float32)
    pos = jax.lax.broadcasted_iota(jnp.int32, (b, s), 1)
    valid = pos < kv_len[:, None]
    if window is not None:
        valid &= pos > (kv_len[:, None] - 1 - window)
    logits = jnp.where(valid[:, None, None, :], logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
    out = jnp.einsum("bhgt,bhtd->bhgd", probs, v,
                     preferred_element_type=jnp.float32)
    return out.reshape(b, hq, d).astype(q.dtype)


# ---------------------------------------------------------------------------
# Chunked cross-entropy: never materialise the full (T, V) logits.
# ---------------------------------------------------------------------------
def chunked_softmax_xent(x, emb_out, labels, weights=None, chunk: int = 8192,
                         unroll: bool = False):
    """x: (T, D); emb_out: (V, D); labels: (T,) int32; weights: (T,) or None.
    Returns (sum_nll, sum_weight)."""
    t, d = x.shape
    chunk = min(chunk, t)
    assert t % chunk == 0, (t, chunk)
    n_chunks = t // chunk
    xc = x.reshape(n_chunks, chunk, d)
    lc = labels.reshape(n_chunks, chunk)
    wc = (weights.reshape(n_chunks, chunk) if weights is not None
          else jnp.ones_like(lc, jnp.float32))

    @jax.checkpoint
    def body(carry, inp):
        nll_sum, w_sum = carry
        xb, lb, wb = inp
        logits = jnp.einsum("td,vd->tv", xb, emb_out,
                            preferred_element_type=jnp.float32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, lb[:, None], axis=-1)[:, 0]
        nll = (lse - gold) * wb
        return (nll_sum + jnp.sum(nll), w_sum + jnp.sum(wb)), None

    (nll_sum, w_sum), _ = jax.lax.scan(
        body, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)),
        (xc, lc, wc), unroll=n_chunks if unroll else 1)
    return nll_sum, w_sum


# ---------------------------------------------------------------------------
# Parameter init helpers
# ---------------------------------------------------------------------------
def dense_init(key, shape, in_axis: int = 0, dtype=jnp.bfloat16):
    fan_in = shape[in_axis]
    std = 1.0 / math.sqrt(fan_in)
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32)
            * std).astype(dtype)


def split_keys(key, n: int):
    return list(jax.random.split(key, n))


@dataclasses.dataclass
class KeyGen:
    key: jax.Array

    def __call__(self):
        self.key, sub = jax.random.split(self.key)
        return sub
