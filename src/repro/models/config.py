"""Architecture + shape configuration for every assigned model family."""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff_expert: int
    n_shared: int = 0
    n_dense_layers: int = 0          # leading dense-FFN layers (deepseek-v2)
    d_ff_dense: int = 0              # their intermediate size
    router_groups: int = 64          # token groups for sorted dispatch
    capacity_factor: float = 1.25


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    kv_lora: int = 512
    q_lora: int = 1536
    d_nope: int = 128
    d_rope: int = 64
    v_head_dim: int = 128


@dataclasses.dataclass(frozen=True)
class RGLRUConfig:
    pattern: Tuple[str, ...] = ("rglru", "rglru", "attn")  # Griffin 2:1
    conv_width: int = 4
    lru_width: int = 0               # 0 -> d_model
    window: int = 2048               # local-attention window


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_state: int = 128
    head_dim: int = 64               # SSD P
    d_conv: int = 4
    expand: int = 2
    chunk: int = 256                 # SSD chunk length


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                      # dense | moe | mla | rglru | ssm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0                # 0 -> d_model // n_heads
    qkv_bias: bool = False
    encoder_only: bool = False       # bidirectional, no decode entry point
    inputs: str = "tokens"           # "tokens" | "embeddings" (audio/vlm stubs)
    mrope: bool = False              # Qwen2-VL multimodal rotary (3 sections)
    rope_theta: float = 1e6
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    rglru: Optional[RGLRUConfig] = None
    ssm: Optional[SSMConfig] = None
    # numerics / execution
    param_dtype: str = "bfloat16"
    remat: bool = True
    loss_chunk: int = 8192           # vocab-softmax token chunking
    attn_q_chunk: int = 512          # blockwise-attention tile sizes (XLA path)
    attn_k_chunk: int = 1024
    # --- dry-run accounting knobs (see launch/dryrun.py) -------------------
    # XLA cost_analysis counts a while-loop body once; exact_count unrolls
    # the *inner* scans (attention pairs, SSD chunks, loss chunks) so they
    # are counted fully, and scan_repeats=2 runs each layer stack twice so
    # the cost delta isolates exactly one layer body.
    exact_count: bool = False
    scan_repeats: int = 1

    @property
    def head_dim_(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def vocab_padded(self) -> int:
        return -(-self.vocab // 256) * 256

    def param_count(self) -> int:
        """Analytic parameter count (embeddings included once)."""
        d, l = self.d_model, self.n_layers
        dh = self.head_dim_ if self.n_heads else 0
        emb = self.vocab_padded * d * (1 if self.tie_embeddings else 2)
        if self.family == "ssm":
            s = self.ssm
            d_in = s.expand * d
            nh = d_in // s.head_dim
            per = (d * (2 * d_in + 2 * s.d_state + nh)   # in_proj (z,x,B,C,dt)
                   + s.d_conv * (d_in + 2 * s.d_state)   # conv
                   + 2 * nh                              # A_log, D
                   + d_in                                # gated-norm scale
                   + d_in * d + d)                       # out_proj + norm
            return emb + l * per
        if self.family == "mla":
            m, q = self.mla, self.moe
            attn = (d * m.q_lora + m.q_lora * self.n_heads * (m.d_nope + m.d_rope)
                    + d * (m.kv_lora + m.d_rope)
                    + m.kv_lora * self.n_heads * (m.d_nope + m.v_head_dim)
                    + self.n_heads * m.v_head_dim * d)
            moe_ffn = 3 * d * q.d_ff_expert * (q.n_experts + q.n_shared) + d * q.n_experts
            dense_ffn = 3 * d * q.d_ff_dense
            per_moe = attn + moe_ffn + 2 * d
            per_dense = attn + dense_ffn + 2 * d
            return emb + q.n_dense_layers * per_dense + (l - q.n_dense_layers) * per_moe
        if self.family == "moe":
            q = self.moe
            attn = d * self.n_heads * dh + 2 * d * self.n_kv_heads * dh \
                + self.n_heads * dh * d
            ffn = 3 * d * q.d_ff_expert * (q.n_experts + q.n_shared) + d * q.n_experts
            return emb + l * (attn + ffn + 2 * d)
        if self.family == "rglru":
            r = self.rglru
            w = r.lru_width or d
            n_attn = sum(1 for i in range(l) if r.pattern[i % len(r.pattern)] == "attn")
            n_rec = l - n_attn
            attn = d * self.n_heads * dh + 2 * d * self.n_kv_heads * dh \
                + self.n_heads * dh * d
            rec = 2 * d * w + r.conv_width * w + 3 * w + w * d  # in(x2), conv, gates, out
            ffn = 3 * d * self.d_ff
            return emb + n_attn * (attn + ffn + 2 * d) + n_rec * (rec + ffn + 2 * d)
        # dense
        attn = d * self.n_heads * dh + 2 * d * self.n_kv_heads * dh \
            + self.n_heads * dh * d
        ffn = 3 * d * self.d_ff
        return emb + l * (attn + ffn + 2 * d)

    def active_param_count(self) -> int:
        """Per-token active parameters (= param_count for non-MoE)."""
        if self.moe is None:
            return self.param_count()
        q = self.moe
        full_moe_ffn = 3 * self.d_model * q.d_ff_expert * (q.n_experts + q.n_shared)
        active_ffn = 3 * self.d_model * q.d_ff_expert * (q.top_k + q.n_shared)
        n_moe_layers = self.n_layers - q.n_dense_layers
        return self.param_count() - n_moe_layers * (full_moe_ffn - active_ffn)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str                        # train_4k | prefill_32k | decode_32k | long_500k
    kind: str                        # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES = {
    "train_4k": ShapeConfig("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeConfig("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeConfig("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeConfig("long_500k", "decode", 524_288, 1),
}
