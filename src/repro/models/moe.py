"""Mixture-of-Experts layer: grouped, sorted, capacity-bounded dispatch.

Design (1000-node posture): tokens are split into ``router_groups`` groups
laid out along the data axis, so the argsort used for expert bucketing is
*local to a group* — the only cross-device movement is the (G->data,
E->model) dispatch, which GSPMD lowers to the canonical expert-parallel
all-to-all.  Capacity is exact-dropless whenever ``Tg * top_k <= capacity``
(always true at decode), and capacity-factor-bounded at scale.
"""
from __future__ import annotations

import dataclasses
import functools
import math

import jax
import jax.numpy as jnp

from repro.dist import partition as _dist

from .common import dense_init
from .config import MoEConfig


def init_moe_ffn(keygen, d_model: int, cfg: MoEConfig, dtype=jnp.bfloat16):
    e, f = cfg.n_experts, cfg.d_ff_expert
    p = {
        "router": dense_init(keygen(), (d_model, e), dtype=jnp.float32),
        "w_gate": dense_init(keygen(), (e, d_model, f), in_axis=1, dtype=dtype),
        "w_up": dense_init(keygen(), (e, d_model, f), in_axis=1, dtype=dtype),
        "w_down": dense_init(keygen(), (e, f, d_model), in_axis=1, dtype=dtype),
    }
    if cfg.n_shared:
        fs = cfg.n_shared * cfg.d_ff_expert
        p["ws_gate"] = dense_init(keygen(), (d_model, fs), dtype=dtype)
        p["ws_up"] = dense_init(keygen(), (d_model, fs), dtype=dtype)
        p["ws_down"] = dense_init(keygen(), (fs, d_model), dtype=dtype)
    return p


def _capacity(tg: int, cfg: MoEConfig) -> int:
    cap = int(math.ceil(tg * cfg.top_k * cfg.capacity_factor / cfg.n_experts))
    cap = max(cap, 1)
    # round to a lane-friendly multiple unless exact-dropless is smaller
    cap = min(-(-cap // 8) * 8, tg * cfg.top_k)
    return max(cap, 1)


def moe_ffn(params, x, cfg: MoEConfig, *, norm_topk: bool = True):
    """x: (T, D) -> (T, D), plus aux dict with load-balance/z losses."""
    t, d = x.shape
    g = cfg.router_groups
    while t % g:
        g //= 2
    g = max(g, 1)
    tg = t // g
    e, k = cfg.n_experts, cfg.top_k
    cap = _capacity(tg, cfg)

    xg = x.reshape(g, tg, d)
    logits = jnp.einsum("gtd,de->gte", xg.astype(jnp.float32),
                        params["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)                        # (G,Tg,E)
    gates, ids = jax.lax.top_k(probs, k)                           # (G,Tg,k)
    if norm_topk:
        gates = gates / jnp.sum(gates, axis=-1, keepdims=True)

    # ---- sorted dispatch within each group --------------------------------
    # NB: counts via bincount, NOT one_hot — a (G, Tg*k, E) one-hot is
    # terabytes at scale (observed 131 GiB/device on deepseek-v2 train_4k)
    flat_ids = ids.reshape(g, tg * k)
    flat_gates = gates.reshape(g, tg * k)
    order = jnp.argsort(flat_ids, axis=-1)                         # (G, Tg*k)
    sorted_ids = jnp.take_along_axis(flat_ids, order, axis=-1)
    sorted_tok = order // k                                        # token index
    counts = jax.vmap(lambda i: jnp.bincount(i, length=e))(flat_ids)

    # ---- load-balance aux (Switch-style) + router z-loss -----------------
    me = jnp.mean(probs, axis=(0, 1))                              # (E,)
    ce = jnp.sum(counts, axis=0).astype(jnp.float32) / (t * k)     # (E,)
    aux_loss = e * jnp.sum(me * ce)
    z_loss = jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2)
    starts = jnp.cumsum(counts, axis=-1) - counts                  # (G, E)
    pos_in_seg = (jnp.arange(tg * k)[None, :]
                  - jnp.take_along_axis(starts, sorted_ids, axis=-1))
    keep = pos_in_seg < cap
    slot = jnp.where(keep, pos_in_seg, cap)                        # cap = drop

    def scatter_group(xs, s_ids, s_tok, s_slot):
        buf = jnp.zeros((e, cap, d), xs.dtype)
        return buf.at[s_ids, s_slot].set(xs[s_tok], mode="drop")

    dispatched = jax.vmap(scatter_group)(xg, sorted_ids, sorted_tok, slot)
    # dispatched: (G, E, C, D) — G on data, E on model => EP all-to-all
    dispatched = _dist.shard_named(dispatched, ("D", "T", "-", "-"))

    h = jnp.einsum("gecd,edf->gecf", dispatched, params["w_gate"])
    u = jnp.einsum("gecd,edf->gecf", dispatched, params["w_up"])
    act = jax.nn.silu(h.astype(jnp.float32)).astype(u.dtype) * u
    out = jnp.einsum("gecf,efd->gecd", act, params["w_down"])

    def gather_group(buf, s_ids, s_slot):
        return buf.at[s_ids, s_slot].get(mode="fill", fill_value=0)

    y_sorted = jax.vmap(gather_group)(out, sorted_ids, slot)       # (G,Tg*k,D)
    y_sorted = y_sorted * jnp.where(
        keep, jnp.take_along_axis(flat_gates, order, axis=-1), 0.0
    )[..., None].astype(y_sorted.dtype)

    inv = jnp.argsort(order, axis=-1)
    y_assign = jnp.take_along_axis(y_sorted, inv[..., None], axis=1)
    y = jnp.sum(y_assign.reshape(g, tg, k, d), axis=2)

    if "ws_gate" in params:  # shared experts: dense SwiGLU over every token
        hs = jnp.einsum("gtd,df->gtf", xg, params["ws_gate"])
        us = jnp.einsum("gtd,df->gtf", xg, params["ws_up"])
        ys = jnp.einsum("gtf,fd->gtd",
                        jax.nn.silu(hs.astype(jnp.float32)).astype(us.dtype) * us,
                        params["ws_down"])
        y = y + ys

    return y.reshape(t, d), {"moe_aux": aux_loss, "moe_z": z_loss}
