"""Fault-tolerant training driver.

    PYTHONPATH=src python -m repro.launch.train --arch qwen2.5-3b --reduced \\
        --steps 300 --batch 8 --seq 256 --ckpt-dir /tmp/run1

Production posture in miniature: deterministic-by-step data (restart-safe
without data-service state), periodic + preemption-triggered atomic
checkpoints, automatic resume from the latest committed step, SIGTERM ->
barrier -> checkpoint -> exit 143 (the k8s/Borg preemption contract), and
per-step heartbeat lines a fleet supervisor can parse (see
launch/elastic.py for the re-mesh side).
"""
from __future__ import annotations

import argparse
import dataclasses
import signal
import sys
import time

import jax
import numpy as np

from repro import configs
from repro.checkpoint import Checkpointer
from repro.data import DataConfig, SyntheticLM
from repro.dist import partition
from repro.launch.mesh import make_debug_mesh, make_production_mesh
from repro.models import api
from repro.optim import adamw
from repro.train import make_train_step

EXIT_PREEMPTED = 143


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=configs.ARCH_IDS, default="qwen2.5-3b")
    ap.add_argument("--reduced", action="store_true",
                    help="train the reduced (smoke) config of the arch")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--peak-lr", type=float, default=3e-3)
    ap.add_argument("--production-mesh", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--d-model", type=int, default=0,
                    help="override width (scaling the reduced config)")
    ap.add_argument("--n-layers", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = (configs.get_reduced(args.arch) if args.reduced
           else configs.get_config(args.arch))
    if args.d_model:
        cfg = dataclasses.replace(cfg, d_model=args.d_model)
    if args.n_layers:
        cfg = dataclasses.replace(cfg, n_layers=args.n_layers)

    mesh = (make_production_mesh() if args.production_mesh
            else make_debug_mesh())
    partition.set_mesh(mesh)
    print(f"mesh: {dict(mesh.shape)}  arch: {cfg.name}  "
          f"params~{cfg.param_count()/1e6:.1f}M")

    data = SyntheticLM(DataConfig(
        vocab=cfg.vocab, seq_len=args.seq, global_batch=args.batch,
        seed=args.seed, inputs=cfg.inputs, d_model=cfg.d_model,
        mrope=cfg.mrope))

    params = api.init_params(cfg, jax.random.key(args.seed))
    opt_state = adamw.init(params)
    pspecs = partition.param_specs(params, mesh)
    from jax.sharding import NamedSharding, PartitionSpec as P
    named = lambda tree: jax.tree.map(
        lambda s: NamedSharding(mesh, s), tree,
        is_leaf=lambda x: isinstance(x, P))
    params = jax.device_put(params, named(pspecs))
    opt_state = jax.device_put(opt_state, named(
        {"m": pspecs, "v": pspecs, "count": P()}))

    step0 = 0
    ckpt = Checkpointer(args.ckpt_dir) if args.ckpt_dir else None
    if ckpt and ckpt.latest_step() is not None:
        step0 = ckpt.latest_step()
        state = ckpt.restore(
            step0, {"params": params, "opt": opt_state},
            {"params": named(pspecs),
             "opt": named({"m": pspecs, "v": pspecs, "count": P()})})
        params, opt_state = state["params"], state["opt"]
        print(f"resumed from step {step0}")

    train_step = make_train_step(cfg, peak_lr=args.peak_lr,
                                 total_steps=args.steps)
    bspecs = named(partition.batch_specs(data.at_step(0), mesh))
    jstep = jax.jit(train_step, donate_argnums=(0, 1))

    preempted = {"flag": False}

    def _sigterm(signum, frame):
        preempted["flag"] = True

    signal.signal(signal.SIGTERM, _sigterm)

    losses = []
    t_start = time.time()
    for step in range(step0, args.steps):
        batch = jax.tree.map(jax.device_put, data.at_step(step), bspecs)
        params, opt_state, metrics = jstep(
            params, opt_state, batch, np.int32(step))
        loss = float(metrics["loss"])
        losses.append(loss)
        if step % 10 == 0 or step == args.steps - 1:
            dt = time.time() - t_start
            print(f"HEARTBEAT step={step} loss={loss:.4f} "
                  f"lr={float(metrics['lr']):.2e} "
                  f"gnorm={float(metrics['grad_norm']):.3f} "
                  f"elapsed={dt:.1f}s", flush=True)
        if ckpt and (step + 1) % args.ckpt_every == 0:
            ckpt.save_async(step + 1, {"params": params, "opt": opt_state},
                            extra={"loss": loss})
        if preempted["flag"]:
            print(f"SIGTERM at step {step}: checkpoint + exit "
                  f"{EXIT_PREEMPTED}", flush=True)
            if ckpt:
                ckpt.save(step + 1, {"params": params, "opt": opt_state},
                          extra={"loss": loss, "preempted": True})
            partition.set_mesh(None)
            return EXIT_PREEMPTED

    if ckpt:
        ckpt.save(args.steps, {"params": params, "opt": opt_state},
                  extra={"loss": losses[-1]})
        ckpt.wait()
    partition.set_mesh(None)
    print(f"final loss {losses[-1]:.4f} (first {losses[0]:.4f})")
    return 0


if __name__ == "__main__":
    sys.exit(main())
