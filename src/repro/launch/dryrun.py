import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x input shape) on
the production meshes and extract the roofline terms.

For each cell this prints/records:
  * compiled.memory_analysis()  — proves the cell fits per-device HBM
  * compiled.cost_analysis()    — HLO FLOPs / bytes for §Roofline
  * collective bytes parsed from the optimized HLO (all-gather, all-reduce,
    reduce-scatter, all-to-all, collective-permute operand sizes)

Scan correction: XLA's cost_analysis counts a while-loop body ONCE, so all
scanned-layer models undercount by ~L; we additionally lower a single-layer
body with identical shardings and report corrected = full + (L-1) * body.

Usage:
  python -m repro.launch.dryrun --arch qwen2.5-3b --shape train_4k
  python -m repro.launch.dryrun --all --mesh both --out results/dryrun
"""
import argparse
import json
import pathlib
import sys
import time

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import configs
from repro.dist import partition
from repro.launch.mesh import make_production_mesh
from repro.models import SHAPES, api
from repro.models.config import ArchConfig, ShapeConfig
from repro.optim import adamw
from repro.roofline import report
from repro.roofline.collectives import collective_bytes_from_hlo
from repro.train import step as train_step_mod

SDS = jax.ShapeDtypeStruct


# ---------------------------------------------------------------------------
# Abstract inputs (ShapeDtypeStruct stand-ins, no allocation)
# ---------------------------------------------------------------------------
def input_specs(cfg: ArchConfig, shape: ShapeConfig) -> dict:
    """Abstract model inputs for one cell."""
    b, s = shape.global_batch, shape.seq_len
    batch = {}
    if shape.kind == "decode":
        batch["tokens"] = SDS((b,), jnp.int32)
        return batch
    if cfg.inputs == "embeddings":
        batch["embeds"] = SDS((b, s, cfg.d_model), jnp.bfloat16)
    else:
        batch["tokens"] = SDS((b, s), jnp.int32)
    if shape.kind == "train":
        batch["labels"] = SDS((b, s), jnp.int32)
    if cfg.mrope:
        batch["positions"] = SDS((3, b, s), jnp.int32)
    return batch


def _abstract(tree):
    return jax.tree.map(lambda x: SDS(x.shape, x.dtype), tree)


def _named(tree, mesh):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), tree,
                        is_leaf=lambda x: isinstance(x, P))


# Gradient-accumulation / chunked-admission factors per cell (§Perf
# iterations: activation + MoE-dispatch transients scale with tokens/pass;
# these bring every train/prefill cell under the 16 GiB v5e budget).
TRAIN_MICROBATCHES = {
    "qwen1.5-110b": 4, "command-r-plus-104b": 4, "qwen2-vl-72b": 4,
    "deepseek-v2-236b": 8, "qwen3-moe-235b-a22b": 8, "mamba2-2.7b": 2,
}
PREFILL_MICROBATCHES = {
    "deepseek-v2-236b": 4, "qwen3-moe-235b-a22b": 4,
    "command-r-plus-104b": 2, "qwen1.5-110b": 2, "qwen2-vl-72b": 2,
}


def build_cell(cfg: ArchConfig, shape: ShapeConfig, mesh):
    """Returns (fn, abstract_args tuple, in_shardings tuple, donate)."""
    params = api.abstract_params(cfg)
    batch = input_specs(cfg, shape)
    bspecs = partition.batch_specs(batch, mesh)

    if shape.kind == "train":
        pspecs = partition.param_specs(params, mesh, mode="train")
        opt = jax.eval_shape(adamw.init, params)
        ospecs = {"m": pspecs, "v": pspecs, "count": P()}
        fn = train_step_mod.make_train_step(
            cfg, microbatches=TRAIN_MICROBATCHES.get(cfg.name, 1))
        args = (params, opt, batch, SDS((), jnp.int32))
        shardings = (pspecs, ospecs, bspecs, P())
        # donated buffers only alias when output shardings match exactly
        metrics = jax.eval_shape(fn, params, opt, batch, SDS((), jnp.int32))[2]
        out_shardings = (pspecs, ospecs,
                         jax.tree.map(lambda _: P(), metrics))
        donate = (0, 1)
    elif shape.kind == "prefill":
        pspecs = partition.param_specs(params, mesh, mode="train")
        fn = train_step_mod.make_prefill_step(
            cfg, shape.seq_len,
            microbatches=PREFILL_MICROBATCHES.get(cfg.name, 1))
        args = (params, batch)
        shardings = (pspecs, bspecs)
        logits_s, cache_s = jax.eval_shape(fn, params, batch)
        out_shardings = (P(), partition.cache_specs(cache_s, mesh)
                         if cache_s is not None else P())
        donate = ()
    else:
        # decode: weight-stationary wide TP for dense archs (no per-token
        # FSDP gathers).  MoE archs keep EP+FSDP — wide TP would leave each
        # device with 1/|model| of ALL experts fully materialised (observed
        # 86-90 GiB/dev on deepseek/qwen3 decode).
        mode = "train" if cfg.moe is not None else "serve"
        pspecs = partition.param_specs(params, mesh, mode=mode)
        cache = jax.eval_shape(
            lambda: api.init_cache(cfg, shape.global_batch, shape.seq_len))
        cspecs = partition.cache_specs(cache, mesh)
        fn = train_step_mod.make_decode_step(cfg)
        args = (params, cache, batch["tokens"])
        shardings = (pspecs, cspecs, partition.batch_specs(
            {"tokens": batch["tokens"]}, mesh)["tokens"])
        out_shardings = (P(), cspecs)
        donate = (1,)
    return fn, args, _named(shardings, mesh), _named(out_shardings, mesh), \
        donate


# ---------------------------------------------------------------------------
def _lower_costs(cfg: ArchConfig, shape: ShapeConfig, mesh,
                 want_memory: bool = False) -> dict:
    fn, args, shardings, out_shardings, donate = build_cell(cfg, shape, mesh)
    jfn = jax.jit(fn, in_shardings=shardings, out_shardings=out_shardings,
                  donate_argnums=donate)
    compiled = jfn.lower(*args).compile()
    cost = report.flat_cost_analysis(compiled)
    out = {
        "flops": cost.get("flops", 0.0),
        "bytes_accessed": cost.get("bytes accessed", 0.0),
        "collective_bytes": collective_bytes_from_hlo(compiled.as_text()),
    }
    if want_memory:
        out["memory"] = _mem_dict(compiled.memory_analysis())
    return out


def body_repeats(cfg: ArchConfig) -> float:
    """How many times the scanned layer body repeats in the real model.
    deepseek-v2's single leading dense-FFN layer is flop-matched to an MoE
    layer by construction (top6*1536 + 2*1536 == 12288 * 3/3), so the
    two-stack delta is treated as two equal bodies."""
    if cfg.family == "rglru":
        pat = len(cfg.rglru.pattern)
        return cfg.n_layers / pat   # super-blocks (+ tail as a fraction)
    return float(cfg.n_layers)


def _n_stacks(cfg: ArchConfig) -> int:
    n = 1
    if cfg.moe and cfg.moe.n_dense_layers:
        n += 1
    if cfg.family == "rglru" and cfg.n_layers % len(cfg.rglru.pattern):
        n += 1
    return n


def run_cell(arch_id: str, shape_name: str, multi_pod: bool,
             verbose: bool = True, layer_correction: bool = True) -> dict:
    """One dry-run cell.  Single-pod cells get three lowerings:
      prod    — production config: memory_analysis + compile proof
      exact1  — exact_count=True: inner scans unrolled, body counted once
      exact2  — exact_count + scan_repeats=2: delta isolates one layer body
    corrected = exact1 + (body_repeats - 1) * (exact2 - exact1) / n_stacks
    (assembled in repro.roofline.report).  Multi-pod cells compile-prove
    only (the roofline table is single-pod per the spec)."""
    import dataclasses
    cfg = configs.get_config(arch_id)
    shape = SHAPES[shape_name]
    ok, why = configs.applicable(cfg, shape)
    if not ok:
        return {"arch": arch_id, "shape": shape_name,
                "mesh": "multi" if multi_pod else "single",
                "status": "skipped", "reason": why}

    mesh = make_production_mesh(multi_pod=multi_pod)
    partition.set_mesh(mesh)
    t0 = time.time()
    try:
        with mesh:
            result = {
                "arch": arch_id, "shape": shape_name,
                "mesh": "multi" if multi_pod else "single",
                "status": "ok", "n_devices": mesh.size,
                "prod": _lower_costs(cfg, shape, mesh, want_memory=True),
            }
            if layer_correction and not multi_pod:
                # coarser tiles in accounting mode: same FLOP coverage
                # (within diagonal-block rounding), ~10x fewer unrolled
                # bodies => tractable compile times
                acct = dict(exact_count=True, attn_q_chunk=2048,
                            attn_k_chunk=2048, loss_chunk=32768)
                cfg1 = dataclasses.replace(cfg, **acct)
                cfg2 = dataclasses.replace(cfg, scan_repeats=2, **acct)
                result["exact1"] = _lower_costs(cfg1, shape, mesh)
                result["exact2"] = _lower_costs(cfg2, shape, mesh)
                result["body_repeats"] = body_repeats(cfg)
                result["n_stacks"] = _n_stacks(cfg)
            result["compile_s"] = round(time.time() - t0, 1)
    except Exception as e:
        result = {
            "arch": arch_id, "shape": shape_name,
            "mesh": "multi" if multi_pod else "single",
            "status": "error", "error": f"{type(e).__name__}: {e}"[:2000],
            "compile_s": round(time.time() - t0, 1),
        }
    finally:
        partition.set_mesh(None)
    if verbose:
        _print_cell(result)
    return result


def _mem_dict(mem) -> dict:
    if mem is None:
        return {}
    out = {}
    for k in ("argument_size_in_bytes", "output_size_in_bytes",
              "temp_size_in_bytes", "generated_code_size_in_bytes",
              "alias_size_in_bytes"):
        v = getattr(mem, k, None)
        if v is not None:
            out[k] = int(v)
    return out


def _print_cell(r: dict) -> None:
    tag = f"{r['arch']} x {r['shape']} [{r['mesh']}]"
    if r["status"] == "skipped":
        print(f"SKIP  {tag}: {r['reason']}")
    elif r["status"] == "error":
        print(f"FAIL  {tag}: {r['error'][:300]}")
    else:
        p = r["prod"]
        mem = p.get("memory", {})
        per_dev = (mem.get("argument_size_in_bytes", 0)
                   + mem.get("temp_size_in_bytes", 0))
        print(f"OK    {tag}: {r['compile_s']}s compile, "
              f"flops={p['flops']:.3e}, bytes={p['bytes_accessed']:.3e}, "
              f"collective={p['collective_bytes']:.3e}, "
              f"mem/device={per_dev/2**30:.2f} GiB")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=configs.ARCH_IDS)
    ap.add_argument("--shape", choices=tuple(SHAPES))
    ap.add_argument("--mesh", choices=("single", "multi", "both"),
                    default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default=None,
                    help="directory for per-cell JSON records")
    ap.add_argument("--no-layer-correction", action="store_true")
    args = ap.parse_args(argv)

    cells = []
    if args.all:
        for aid in configs.ARCH_IDS:
            for sname in SHAPES:
                cells.append((aid, sname))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells.append((args.arch, args.shape))
    meshes = {"single": (False,), "multi": (True,),
              "both": (False, True)}[args.mesh]

    outdir = pathlib.Path(args.out) if args.out else None
    if outdir:
        outdir.mkdir(parents=True, exist_ok=True)
    failures = 0
    for aid, sname in cells:
        for mp in meshes:
            key = f"{aid}__{sname}__{'multi' if mp else 'single'}"
            if outdir and (outdir / f"{key}.json").exists():
                print(f"CACHED {key}")
                continue
            r = run_cell(aid, sname, mp,
                         layer_correction=not args.no_layer_correction)
            if r["status"] == "error":
                failures += 1
            if outdir:
                (outdir / f"{key}.json").write_text(json.dumps(r, indent=1))
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
