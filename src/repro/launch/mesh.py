"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state — the dry-run sets XLA_FLAGS before first jax use.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips/pod; 2 pods = 512 chips multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(n_devices: int | None = None, *, multi_pod: bool = False):
    """Small mesh over whatever devices exist (tests / CPU smoke)."""
    n = n_devices or len(jax.devices())
    if multi_pod and n >= 8:
        return jax.make_mesh((2, 2, n // 4), ("pod", "data", "model"))
    if n == 1:
        return jax.make_mesh((1, 1), ("data", "model"))
    return jax.make_mesh((n // 2, 2), ("data", "model"))
