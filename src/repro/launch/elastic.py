"""Elastic coordination: heartbeat tracking, straggler/failure exclusion,
and re-mesh planning.

Control plane for the 1000+ node posture.  Hosts post heartbeats every
step (the train driver prints them; a supervisor forwards them here).  When
a host misses ``dead_after`` seconds it is excluded and a new mesh plan is
computed from the survivors; the data plane then (1) restores the latest
committed checkpoint with ``Checkpointer.restore`` onto the new mesh —
checkpoints are topology-agnostic, so any (pod, data, model) factorisation
works — and (2) resumes from the deterministic-by-step data pipeline with
no data-service state.  Straggler mitigation: hosts whose step latency
exceeds ``straggler_factor`` x the fleet median are flagged and excluded at
the next planned re-mesh rather than immediately (avoids thrash).
"""
from __future__ import annotations

import dataclasses
import math
import time
from typing import Optional


@dataclasses.dataclass
class HostState:
    host_id: str
    last_heartbeat: float
    last_step: int = -1
    step_latency: float = 0.0
    excluded: bool = False


class ElasticCoordinator:
    def __init__(self, n_hosts: int, chips_per_host: int = 4,
                 dead_after: float = 60.0, straggler_factor: float = 2.0,
                 clock=time.monotonic):
        self.chips_per_host = chips_per_host
        self.dead_after = dead_after
        self.straggler_factor = straggler_factor
        self.clock = clock
        now = clock()
        self.hosts = {f"host{i:04d}": HostState(f"host{i:04d}", now)
                      for i in range(n_hosts)}
        self.generation = 0

    # ---------------------------------------------------------- heartbeats
    def heartbeat(self, host_id: str, step: int,
                  step_latency: float = 0.0) -> None:
        h = self.hosts[host_id]
        h.last_heartbeat = self.clock()
        h.last_step = step
        h.step_latency = step_latency

    # ------------------------------------------------------------- health
    def dead_hosts(self) -> list:
        now = self.clock()
        return [h.host_id for h in self.hosts.values()
                if not h.excluded
                and now - h.last_heartbeat > self.dead_after]

    def stragglers(self) -> list:
        lats = sorted(h.step_latency for h in self.hosts.values()
                      if not h.excluded and h.step_latency > 0)
        if len(lats) < 4:
            return []
        median = lats[len(lats) // 2]
        return [h.host_id for h in self.hosts.values()
                if not h.excluded
                and h.step_latency > self.straggler_factor * median]

    # --------------------------------------------------------------- plan
    def alive_chips(self) -> int:
        return sum(self.chips_per_host for h in self.hosts.values()
                   if not h.excluded)

    def plan_mesh(self) -> Optional[dict]:
        """Largest (data, model) factorisation that fits the healthy chips.
        model axis is kept at 16 where possible (weights must still fit);
        data absorbs the shrink — the batch is re-sharded, not resized."""
        chips = self.alive_chips()
        model = 16 if chips >= 16 else chips
        data = chips // model
        if data == 0:
            return None
        # power-of-two data axis keeps the FSDP collectives balanced
        data = 2 ** int(math.log2(data))
        return {"mesh_shape": (data, model), "axes": ("data", "model"),
                "chips_used": data * model, "generation": self.generation}

    def handle_failures(self) -> Optional[dict]:
        """Exclude dead hosts + known stragglers; return a new mesh plan if
        anything changed, else None."""
        to_exclude = set(self.dead_hosts()) | set(self.stragglers())
        if not to_exclude:
            return None
        for hid in to_exclude:
            self.hosts[hid].excluded = True
        self.generation += 1
        return self.plan_mesh()
