"""Serving driver: continuous-batching engine over synthetic requests.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2.5-3b --reduced \\
        --requests 8 --slots 4
"""
from __future__ import annotations

import argparse
import sys
import time

import jax
import numpy as np

from repro import configs
from repro.models import api
from repro.serve import Engine, Request


def _mesh_shape(text: str) -> tuple:
    try:
        d, m = (int(v) for v in text.lower().split("x"))
        if d < 1 or m < 1:
            raise ValueError
    except ValueError:
        raise argparse.ArgumentTypeError(
            f"expected DxM with positive ints, e.g. 2x4 (got {text!r})")
    return d, m


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=configs.ARCH_IDS, default="qwen2.5-3b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=12)
    ap.add_argument("--max-seq", type=int, default=256)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--mesh", default=None, metavar="DxM", type=_mesh_shape,
                    help="serve on a (data, model) mesh, e.g. 2x4 "
                         "(needs data*model visible devices)")
    args = ap.parse_args(argv)

    mesh = None
    if args.mesh:
        mesh = jax.make_mesh(args.mesh, ("data", "model"))

    cfg = configs.get_reduced(args.arch)
    if cfg.encoder_only:
        print(f"{args.arch} is encoder-only: no serving path")
        return 2
    params = api.init_params(cfg, jax.random.key(args.seed))
    engine = Engine(cfg, params, slots=args.slots, max_seq=args.max_seq,
                    mesh=mesh)

    rng = np.random.default_rng(args.seed)
    t0 = time.time()
    for i in range(args.requests):
        plen = int(rng.integers(4, 24))
        engine.submit(Request(
            rid=i, prompt=rng.integers(0, cfg.vocab, plen).astype(np.int32),
            max_new=args.max_new))
    finished = engine.run()
    dt = time.time() - t0
    tokens = sum(len(r.generated) for r in finished)
    print(f"served {len(finished)} requests, {tokens} tokens "
          f"in {dt:.1f}s ({tokens/dt:.1f} tok/s on CPU)")
    for r in finished[:3]:
        print(f"  req{r.rid}: prompt[:4]={r.prompt[:4].tolist()} "
              f"-> {r.generated[:8]}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
