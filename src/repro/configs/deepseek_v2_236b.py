"""deepseek-v2-236b [moe]: 60L d=5120 128H MLA (kv_lora=512) vocab=102400;
MoE: 2 shared + 160 routed experts, top-6, d_ff_expert=1536; first layer is
a dense FFN (d_ff=12288).  [arXiv:2405.04434]"""
from repro.models.config import ArchConfig, MLAConfig, MoEConfig

ARCH_ID = "deepseek-v2-236b"


def config() -> ArchConfig:
    return ArchConfig(
        name=ARCH_ID, family="mla", n_layers=60, d_model=5120,
        n_heads=128, n_kv_heads=128, d_ff=0, vocab=102400, head_dim=128,
        mla=MLAConfig(kv_lora=512, q_lora=1536, d_nope=128, d_rope=64,
                      v_head_dim=128),
        moe=MoEConfig(n_experts=160, top_k=6, d_ff_expert=1536, n_shared=2,
                      n_dense_layers=1, d_ff_dense=12288))


def reduced() -> ArchConfig:
    return ArchConfig(
        name=ARCH_ID + "-smoke", family="mla", n_layers=3, d_model=64,
        n_heads=4, n_kv_heads=4, d_ff=0, vocab=128, head_dim=16,
        mla=MLAConfig(kv_lora=32, q_lora=48, d_nope=16, d_rope=8,
                      v_head_dim=16),
        moe=MoEConfig(n_experts=8, top_k=2, d_ff_expert=32, n_shared=2,
                      n_dense_layers=1, d_ff_dense=128, router_groups=4),
        attn_q_chunk=32, attn_k_chunk=32, loss_chunk=64)
