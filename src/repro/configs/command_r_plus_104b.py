"""command-r-plus-104b [dense]: 64L d=12288 96H (GQA kv=8) d_ff=33792
vocab=256000, no bias.  [hf:CohereForAI/c4ai-command-r-v01 (family)]"""
from repro.models.config import ArchConfig

ARCH_ID = "command-r-plus-104b"


def config() -> ArchConfig:
    return ArchConfig(
        name=ARCH_ID, family="dense", n_layers=64, d_model=12288,
        n_heads=96, n_kv_heads=8, d_ff=33792, vocab=256000)


def reduced() -> ArchConfig:
    return ArchConfig(
        name=ARCH_ID + "-smoke", family="dense", n_layers=3, d_model=96,
        n_heads=6, n_kv_heads=2, d_ff=256, vocab=160,
        attn_q_chunk=32, attn_k_chunk=32, loss_chunk=64)
