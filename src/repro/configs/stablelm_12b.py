"""stablelm-12b [dense]: 40L d=5120 32H (GQA kv=8) d_ff=13824 vocab=100352.
[hf:stabilityai/stablelm-2-1_6b (family); scaled per assignment]"""
from repro.models.config import ArchConfig

ARCH_ID = "stablelm-12b"


def config() -> ArchConfig:
    return ArchConfig(
        name=ARCH_ID, family="dense", n_layers=40, d_model=5120,
        n_heads=32, n_kv_heads=8, d_ff=13824, vocab=100352)


def reduced() -> ArchConfig:
    return ArchConfig(
        name=ARCH_ID + "-smoke", family="dense", n_layers=3, d_model=80,
        n_heads=4, n_kv_heads=2, d_ff=192, vocab=128,
        attn_q_chunk=32, attn_k_chunk=32, loss_chunk=64)
