"""Architecture registry: ``--arch <id>`` resolution + per-cell skip rules.

Every assigned architecture is a module with ``config()`` (the exact
published dims) and ``reduced()`` (a small same-family config for CPU smoke
tests).  ``applicable(arch, shape)`` encodes the assignment's skip rules:
encoder-only archs have no decode step, and ``long_500k`` runs only for
sub-quadratic (SSM / hybrid-local-attention) families.
"""
from __future__ import annotations

from repro.models.config import SHAPES, ArchConfig, ShapeConfig

from . import (
    command_r_plus_104b, deepseek_v2_236b, hubert_xlarge, mamba2_2_7b,
    qwen1_5_110b, qwen2_5_3b, qwen2_vl_72b, qwen3_moe_235b,
    recurrentgemma_9b, stablelm_12b,
)

_MODULES = (
    hubert_xlarge, qwen1_5_110b, stablelm_12b, command_r_plus_104b,
    qwen2_5_3b, recurrentgemma_9b, deepseek_v2_236b, qwen3_moe_235b,
    qwen2_vl_72b, mamba2_2_7b,
)

ARCHS = {m.ARCH_ID: m for m in _MODULES}
ARCH_IDS = tuple(ARCHS)


def get_config(arch_id: str) -> ArchConfig:
    return ARCHS[arch_id].config()


def get_reduced(arch_id: str) -> ArchConfig:
    return ARCHS[arch_id].reduced()


# Families with sub-quadratic sequence mixing (run long_500k).
_SUBQUADRATIC = ("rglru", "ssm")


def applicable(cfg: ArchConfig, shape: ShapeConfig) -> tuple:
    """(runnable, reason_if_skipped) — DESIGN.md §Arch-applicability."""
    if cfg.encoder_only and shape.kind == "decode":
        return False, "encoder-only: no decode step"
    if shape.name == "long_500k" and cfg.family not in _SUBQUADRATIC:
        return False, ("pure full-attention arch: 512k quadratic decode is "
                       "not a supported config (see DESIGN.md)")
    return True, ""


def all_cells():
    """Every (arch_id, shape_name) with its applicability verdict."""
    out = []
    for aid in ARCH_IDS:
        cfg = get_config(aid)
        for sname, shape in SHAPES.items():
            ok, why = applicable(cfg, shape)
            out.append((aid, sname, ok, why))
    return out
