"""recurrentgemma-9b [hybrid]: 38L d=4096 16H (MQA kv=1) d_ff=12288
vocab=256000; RG-LRU + 2048-window local attention, 2:1 pattern.
[arXiv:2402.19427]"""
from repro.models.config import ArchConfig, RGLRUConfig

ARCH_ID = "recurrentgemma-9b"


def config() -> ArchConfig:
    return ArchConfig(
        name=ARCH_ID, family="rglru", n_layers=38, d_model=4096,
        n_heads=16, n_kv_heads=1, d_ff=12288, vocab=256000,
        rglru=RGLRUConfig(window=2048), rope_theta=1e4)


def reduced() -> ArchConfig:
    return ArchConfig(
        name=ARCH_ID + "-smoke", family="rglru", n_layers=5, d_model=64,
        n_heads=4, n_kv_heads=1, d_ff=160, vocab=128,
        rglru=RGLRUConfig(window=32, lru_width=64), rope_theta=1e4,
        attn_q_chunk=32, attn_k_chunk=32, loss_chunk=64)
