"""qwen2.5-3b [dense]: 36L d=2048 16H (GQA kv=2) d_ff=11008 vocab=151936,
QKV bias.  [hf:Qwen/Qwen2.5-0.5B (family); scaled per assignment]"""
from repro.models.config import ArchConfig

ARCH_ID = "qwen2.5-3b"


def config() -> ArchConfig:
    return ArchConfig(
        name=ARCH_ID, family="dense", n_layers=36, d_model=2048,
        n_heads=16, n_kv_heads=2, d_ff=11008, vocab=151936, qkv_bias=True)


def reduced() -> ArchConfig:
    return ArchConfig(
        name=ARCH_ID + "-smoke", family="dense", n_layers=3, d_model=64,
        n_heads=4, n_kv_heads=2, d_ff=176, vocab=128, qkv_bias=True,
        attn_q_chunk=32, attn_k_chunk=32, loss_chunk=64)
