"""qwen3-moe-235b-a22b [moe]: 94L d=4096 64H (GQA kv=4, head_dim=128)
vocab=151936; 128 experts top-8, d_ff_expert=1536.
[hf:Qwen/Qwen3-30B-A3B (family); scaled per assignment]"""
from repro.models.config import ArchConfig, MoEConfig

ARCH_ID = "qwen3-moe-235b-a22b"


def config() -> ArchConfig:
    return ArchConfig(
        name=ARCH_ID, family="moe", n_layers=94, d_model=4096,
        n_heads=64, n_kv_heads=4, d_ff=0, vocab=151936, head_dim=128,
        moe=MoEConfig(n_experts=128, top_k=8, d_ff_expert=1536))


def reduced() -> ArchConfig:
    return ArchConfig(
        name=ARCH_ID + "-smoke", family="moe", n_layers=3, d_model=64,
        n_heads=4, n_kv_heads=2, d_ff=0, vocab=128, head_dim=16,
        moe=MoEConfig(n_experts=8, top_k=2, d_ff_expert=32, router_groups=4),
        attn_q_chunk=32, attn_k_chunk=32, loss_chunk=64)
