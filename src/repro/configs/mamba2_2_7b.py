"""mamba2-2.7b [ssm]: 64L d=2560 (attention-free) vocab=50280;
SSD with state=128, head_dim=64, expand=2.  [arXiv:2405.21060]"""
from repro.models.config import ArchConfig, SSMConfig

ARCH_ID = "mamba2-2.7b"


def config() -> ArchConfig:
    return ArchConfig(
        name=ARCH_ID, family="ssm", n_layers=64, d_model=2560,
        n_heads=0, n_kv_heads=0, d_ff=0, vocab=50280,
        ssm=SSMConfig(d_state=128, head_dim=64, expand=2, chunk=256))


def reduced() -> ArchConfig:
    return ArchConfig(
        name=ARCH_ID + "-smoke", family="ssm", n_layers=3, d_model=64,
        n_heads=0, n_kv_heads=0, d_ff=0, vocab=128,
        ssm=SSMConfig(d_state=16, head_dim=16, expand=2, chunk=32),
        loss_chunk=64)
