"""hubert-xlarge [audio]: 48L d=1280 16H (kv=16) d_ff=5120 vocab=504.
Encoder-only; the conv waveform frontend is a stub — ``input_specs`` feeds
precomputed frame embeddings (B, S, d_model).  [arXiv:2106.07447]"""
from repro.models.config import ArchConfig

ARCH_ID = "hubert-xlarge"


def config() -> ArchConfig:
    return ArchConfig(
        name=ARCH_ID, family="dense", n_layers=48, d_model=1280,
        n_heads=16, n_kv_heads=16, d_ff=5120, vocab=504,
        encoder_only=True, inputs="embeddings", rope_theta=1e4)


def reduced() -> ArchConfig:
    return ArchConfig(
        name=ARCH_ID + "-smoke", family="dense", n_layers=3, d_model=64,
        n_heads=4, n_kv_heads=4, d_ff=128, vocab=96,
        encoder_only=True, inputs="embeddings", rope_theta=1e4,
        attn_q_chunk=32, attn_k_chunk=32, loss_chunk=64)
