"""qwen1.5-110b [dense]: 80L d=8192 64H (GQA kv=8) d_ff=49152 vocab=152064,
QKV bias.  [hf:Qwen/Qwen1.5-0.5B (family); scaled per assignment]"""
from repro.models.config import ArchConfig

ARCH_ID = "qwen1.5-110b"


def config() -> ArchConfig:
    return ArchConfig(
        name=ARCH_ID, family="dense", n_layers=80, d_model=8192,
        n_heads=64, n_kv_heads=8, d_ff=49152, vocab=152064, qkv_bias=True)


def reduced() -> ArchConfig:
    return ArchConfig(
        name=ARCH_ID + "-smoke", family="dense", n_layers=3, d_model=64,
        n_heads=4, n_kv_heads=2, d_ff=160, vocab=128, qkv_bias=True,
        attn_q_chunk=32, attn_k_chunk=32, loss_chunk=64)
