"""qwen2-vl-72b [vlm]: 80L d=8192 64H (GQA kv=8) d_ff=29568 vocab=152064;
M-RoPE (t/h/w rotary sections), QKV bias.  The vision tower is a stub —
``input_specs`` feeds precomputed patch/text embeddings.  [arXiv:2409.12191]"""
from repro.models.config import ArchConfig

ARCH_ID = "qwen2-vl-72b"


def config() -> ArchConfig:
    return ArchConfig(
        name=ARCH_ID, family="dense", n_layers=80, d_model=8192,
        n_heads=64, n_kv_heads=8, d_ff=29568, vocab=152064,
        qkv_bias=True, mrope=True, inputs="embeddings")


def reduced() -> ArchConfig:
    return ArchConfig(
        name=ARCH_ID + "-smoke", family="dense", n_layers=3, d_model=64,
        n_heads=4, n_kv_heads=2, d_ff=160, vocab=128,
        qkv_bias=True, mrope=True, inputs="embeddings",
        attn_q_chunk=32, attn_k_chunk=32, loss_chunk=64)
