"""Stage 3 — LLM Kernel Writer (paper §3.3).

"This stage lies at the heart of the GPU Kernel Scientist process": it turns
an experiment rubric plus the Base code (with the Reference in context, and
one-step experiment analyses for both) into a complete new kernel module,
and reports which techniques it actually used — which may deviate from the
rubric.  Three writer instances are launched per generation (paper §3.2);
the EvaluationService still serialises their submissions.
"""
from __future__ import annotations

import dataclasses

from . import prompts
from .llm import LLMClient
from .population import Population


@dataclasses.dataclass(frozen=True)
class WrittenKernel:
    source: str
    genome_json: str | None
    report: str


def write(population: Population, basis_id: str, reference_id: str,
          experiment: dict, llm: LLMClient,
          task_text: str = prompts.TASK_TEXT) -> WrittenKernel:
    base = population.get(basis_id)
    ref = population.get(reference_id)

    base_record = population.one_step_analysis(basis_id)
    base_record["source"] = base.source
    base_record["genome"] = base.genome.to_json() if base.genome else None
    ref_record = population.one_step_analysis(reference_id)
    ref_record["source"] = ref.source

    from . import knowledge
    prompt = prompts.writer_prompt(experiment, base_record, ref_record,
                                   knowledge.FINDINGS_DOCUMENT, task_text)
    reply = prompts.extract_reply_json(llm.complete(prompt))

    source = reply["source"]
    genome = reply.get("genome")
    genome_json = None
    if genome is not None:
        import json

        from .genome import KernelGenome
        if isinstance(genome, str):
            genome = json.loads(genome)
        genome["dimension_semantics"] = tuple(genome["dimension_semantics"])
        genome_json = KernelGenome(**genome).to_json()
    return WrittenKernel(source, genome_json, str(reply.get("report", "")))


def fallback_write(population: Population, basis_id: str,
                   experiment: dict) -> WrittenKernel:
    """Deterministic rule-based writer when the LLM stays unusable after
    retries: apply the experiment's machine-readable ``genome_edit`` to the
    Base genome directly (reverting to the Base if the edit is illegal) and
    render the kernel from the template.  A degraded submission beats an
    aborted generation — the evaluation platform remains the judge."""
    from . import codegen
    from .genome import KernelGenome

    base = population.get(basis_id)
    base_genome = base.genome or KernelGenome()
    genome = base_genome
    note = "resubmitting the base genome unchanged"
    edit = experiment.get("genome_edit")
    if edit:
        clean = dict(edit)
        if "dimension_semantics" in clean:
            clean["dimension_semantics"] = tuple(clean["dimension_semantics"])
        try:
            cand = base_genome.replace(**clean)
            if not cand.validate():
                genome = cand
                note = "applied the rubric's genome_edit mechanically"
            else:
                note = ("genome_edit produced an illegal configuration; "
                        "reverted to the base genome")
        except (TypeError, ValueError):
            note = ("genome_edit did not parse against the design space; "
                    "reverted to the base genome")
    source = codegen.render_source(
        genome, experiment.get("description", "(fallback submission)"))
    return WrittenKernel(
        source, genome.to_json(),
        f"(rule-based fallback after LLM failures) {note}.")
