"""The GPU Kernel Scientist closed loop (paper Fig. 1).

    seed kernels -> [ Evolutionary Selector -> Experiment Designer (5 plans,
    pick 3) -> 3x Kernel Writer -> sequential Testing & Evaluation ] * G

Everything the paper's loop records is recorded here: population with
lineage, per-config benchmark timings, experiment descriptions/rubrics,
selection rationales, writer reports, and a generation-by-generation logbook
(used by benchmarks/trajectory.py for the §4.4 discovery-process figure).
"""
from __future__ import annotations

import dataclasses
import json
import pathlib
from typing import Optional

from . import codegen, designer, prompts, selector, writer
from .evaluator import EvaluationService, EvalResult
from .genome import SEED_LIBRARY, SEED_MXU, SEED_NAIVE, KernelGenome
from .llm import LLMClient, ScriptedLLM
from .population import KernelRecord, Population


@dataclasses.dataclass
class GenerationLog:
    generation: int
    selection: dict
    plans: list
    picked: list
    submitted: list            # [(rid, status, geomean_us)]
    best_rid: str
    best_geomean_us: float


class KernelScientist:
    def __init__(self, llm: Optional[LLMClient] = None,
                 service: Optional[EvaluationService] = None,
                 task_text: str = prompts.TASK_TEXT,
                 workdir: Optional[str] = None) -> None:
        self.llm = llm or ScriptedLLM()
        self.service = service or EvaluationService()
        self.task_text = task_text
        self.population = Population()
        self.logbook: list[GenerationLog] = []
        self.workdir = pathlib.Path(workdir) if workdir else None
        if self.workdir:
            self.workdir.mkdir(parents=True, exist_ok=True)

    # ------------------------------------------------------------ seeding
    def seed(self, genomes=(SEED_LIBRARY, SEED_NAIVE, SEED_MXU),
             descriptions=("library implementation (provided baseline)",
                           "direct translation into a Pallas kernel "
                           "(unoptimized: f32 math, per-tile dequant)",
                           "first working MXU kernel (128^3 VMEM tiles)"),
             ) -> None:
        """Paper §3: the process starts from a few seed kernels."""
        assert len(self.population) == 0, "already seeded"
        for genome, desc in zip(genomes, descriptions):
            source = codegen.render_source(genome, desc)
            rec = KernelRecord(
                rid=self.population.new_id(), parents=(), source=source,
                genome=genome,
                experiment={"description": desc, "rubric": "(seed)",
                            "performance": [0, 0], "innovation": 0},
                writer_report="(seed kernel)", generation=0)
            self.population._records[rec.rid] = rec
            self._apply_eval(rec, self.service.submit(source))
        self._persist()

    # --------------------------------------------------------------- loop
    def run_generation(self, generation: int) -> GenerationLog:
        sel = selector.select(self.population, self.llm, self.task_text)
        plans = designer.design(self.population, sel.basis_code,
                                sel.basis_reference, self.llm, self.task_text)
        picked = designer.pick3(plans)

        submitted = []
        for exp in picked:  # three independent writer instances (paper §3.2)
            wk = writer.write(self.population, sel.basis_code,
                              sel.basis_reference, exp, self.llm,
                              self.task_text)
            rec = KernelRecord(
                rid=self.population.new_id(),
                parents=(sel.basis_code, sel.basis_reference),
                source=wk.source,
                genome=(KernelGenome.from_json(wk.genome_json)
                        if wk.genome_json else None),
                experiment={k: exp[k] for k in
                            ("description", "rubric", "performance",
                             "innovation")},
                writer_report=wk.report, generation=generation)
            self.population.add(rec)
            # sequential submission — the platform enforces it too
            self._apply_eval(rec, self.service.submit(wk.source))
            submitted.append((rec.rid, rec.status,
                              rec.score if rec.score != float("inf") else None))

        best = self.population.best()
        log = GenerationLog(
            generation=generation,
            selection=dataclasses.asdict(sel),
            plans=[{k: p[k] for k in ("description", "performance",
                                      "innovation")} for p in plans],
            picked=[p["description"] for p in picked],
            submitted=submitted,
            best_rid=best.rid, best_geomean_us=best.score)
        self.logbook.append(log)
        self._persist()
        return log

    def run(self, generations: int) -> KernelRecord:
        if len(self.population) == 0:
            self.seed()
        start = len(self.logbook) + 1
        for g in range(start, start + generations):
            self.run_generation(g)
        return self.population.best()

    # ------------------------------------------------------------ helpers
    def _apply_eval(self, rec: KernelRecord, res: EvalResult) -> None:
        rec.status = res.status
        rec.error = res.error
        rec.timings_us = dict(res.timings_us)

    def _persist(self) -> None:
        if not self.workdir:
            return
        self.population.save(self.workdir / "population.json")
        (self.workdir / "logbook.json").write_text(json.dumps(
            [dataclasses.asdict(l) for l in self.logbook], indent=1))

    # ------------------------------------------------------------- report
    def trajectory(self) -> list:
        """(generation, best_geomean_us) pairs — the discovery curve."""
        out = []
        best = min((r.score for r in self.population if r.generation == 0),
                   default=float("inf"))
        out.append((0, best))
        for log in self.logbook:
            best = min(best, log.best_geomean_us)
            out.append((log.generation, best))
        return out
