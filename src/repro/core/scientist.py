"""The GPU Kernel Scientist closed loop (paper Fig. 1).

    seed kernels -> [ Evolutionary Selector -> Experiment Designer (5 plans,
    pick 3) -> 3x Kernel Writer -> pooled Testing & Evaluation ] * G

Everything the paper's loop records is recorded here: population with
lineage, per-config benchmark timings, experiment descriptions/rubrics,
selection rationales, writer reports, and a generation-by-generation logbook
(used by benchmarks/trajectory.py for the §4.4 discovery-process figure).

The loop is built for the paper's operating regime — autonomous multi-day
campaigns against a flaky shared evaluation queue (§3.4):

* **Per-submission persistence.**  ``population.json`` + ``state.json`` are
  rewritten atomically after every individual submission (not just every
  generation), so a crash loses at most the one in-flight kernel.
* **Resume.**  ``KernelScientist.resume(workdir, ...)`` reconstructs the
  population, logbook, and any partially-completed generation from the
  persisted state and continues the campaign.  Backend decision state
  (ScriptedLLM jitter counter, EvaluationService noise counter) is restored
  too, so a killed-and-resumed campaign produces a trajectory identical to
  an uninterrupted same-seed run.
* **Retry + fallback.**  Every LLM stage and every evaluation submission is
  retried with exponential backoff (``core.resilience``); a stage that stays
  broken falls back to a deterministic rule-based decision instead of
  aborting the generation.
* **Event log.**  Stage timings, retries, fallbacks, and evaluation outcomes
  stream to ``events.jsonl`` (``core.events``) for the §4.4 figure.
* **Pooled evaluation.**  Submissions go through the ``EvalBackend``
  protocol (``core.evalpool``) — by default an ``EvalPool`` of in-process
  or subprocess workers (``KernelScientist(backend=...)``):
  each writer output is enqueued as soon as it exists, so the writer stage
  overlaps with in-flight evaluations and a generation costs roughly
  ``max(writes) + max(evals)`` instead of ``3 x (write + eval)``.  Results
  are applied and persisted in record-id order (the pool may complete them
  in any order), and the in-flight checkpoint tracks both completed
  (``submitted``) and enqueued-but-unfinished (``pending``) records, so a
  campaign killed mid-drain resumes trajectory-identically — the pending
  kernels' sources are durable and simply re-enqueued.  A content-addressed
  cache in front of the pool returns persisted verdicts for duplicate
  sources without consuming a platform slot.
"""
from __future__ import annotations

import dataclasses
import json
import pathlib
import time
import warnings
from typing import Optional

from . import codegen, designer, prompts, resilience, selector, writer
from .evalpool import (PRIORITY_URGENT, EvalBackend, EvalCache, EvalHandle,
                       EvalPool)
from .events import EventLog
from .evaluator import EvaluationService, EvalResult
from .genome import SEED_LIBRARY, SEED_MXU, SEED_NAIVE, KernelGenome
from .integrity import Integrity
from .llm import LLMClient, ScriptedLLM
from .population import KernelRecord, Population, geomean
from .resilience import CircuitOpenError

#: Sentinel distinguishing "not passed" from an explicit None for the
#: deprecated constructor kwargs.
_UNSET = object()

# v2: "service" holds EvalPool worker states; inflight gained "pending"
# (enqueued-but-unfinished record ids).  v1 files load fine: a bare service
# state dict is treated as the first worker's, and "pending" defaults empty.
# v3: adds "integrity" (audit ledger, quarantine set, breaker states, canary
# reference, consumed wall-clock).  v2 files load fine: a missing section
# leaves the Integrity components at their just-constructed state.
_STATE_SCHEMA = 3


def _errtext(e: BaseException) -> str:
    return f"{type(e).__name__}: {e}"


@dataclasses.dataclass
class GenerationLog:
    generation: int
    selection: dict
    plans: list
    picked: list
    submitted: list            # [(rid, status, geomean_us-or-None)]
    best_rid: str              # "" while the population has no ok member
    best_geomean_us: float     # inf while the population has no ok member

    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        # JSON has no Infinity; json.dumps would emit the non-standard token
        if d["best_geomean_us"] == float("inf"):
            d["best_geomean_us"] = None
        return d

    @staticmethod
    def from_dict(d: dict) -> "GenerationLog":
        d = dict(d)
        if d.get("best_geomean_us") is None:
            d["best_geomean_us"] = float("inf")
        d["submitted"] = [tuple(s) for s in d.get("submitted", [])]
        return GenerationLog(**d)


class KernelScientist:
    def __init__(self, llm: Optional[LLMClient] = None,
                 backend=None,
                 task_text: str = prompts.TASK_TEXT,
                 workdir: Optional[str] = None,
                 retry_policy: Optional[resilience.RetryPolicy] = None,
                 events: Optional[EventLog] = None,
                 sleep=time.sleep,
                 integrity: Optional[Integrity] = None,
                 service=_UNSET,
                 pool=_UNSET,
                 workers=_UNSET,
                 eval_cache=_UNSET) -> None:
        """``backend`` is the single evaluation parameter: either anything
        satisfying the :class:`EvalBackend` protocol (an ``EvalPool``, a
        remote-queue client, a test double) used as-is, or a bare
        ``EvaluationService``-like object (has ``submit``) that is wrapped
        in a one-worker cached ``EvalPool``.  ``None`` wraps a default
        ``EvaluationService()``.

        ``integrity`` is the verdict-trust layer (``core.integrity``):
        timing audits with quorum re-measurement, poison-kernel quarantine,
        per-worker canaries, circuit breakers, and campaign budgets.  The
        default ``Integrity()`` has every component off, so behaviour is
        bit-for-bit what it was without one.

        ``service=`` / ``pool=`` / ``workers=`` / ``eval_cache=`` are
        deprecated shims for the pre-``EvalBackend`` surface: they still
        behave exactly as before but emit ``DeprecationWarning``; construct
        the pool explicitly instead —
        ``backend=EvalPool.of(svc, workers=3, cache=EvalCache(path))``.
        """
        self.llm = llm or ScriptedLLM()
        self.integrity = integrity or Integrity()
        self.task_text = task_text
        self.population = Population()
        self.logbook: list[GenerationLog] = []
        self.retry_policy = retry_policy or resilience.DEFAULT_POLICY
        self._sleep = sleep
        self._seeded = False
        self._inflight: Optional[dict] = None   # partially-run generation
        self.workdir = pathlib.Path(workdir) if workdir else None
        if self.workdir:
            self.workdir.mkdir(parents=True, exist_ok=True)
        self.events = events or EventLog(
            self.workdir / "events.jsonl" if self.workdir else None)
        self.pool: EvalBackend = self._resolve_backend(
            backend, service=service, pool=pool, workers=workers,
            eval_cache=eval_cache)
        self._wire_quarantine()

    def _wire_quarantine(self) -> None:
        """Hand the pool the campaign's quarantine so worker deaths feed it
        and blacklisted hashes are blocked at submit time."""
        if (self.integrity.quarantine is not None
                and isinstance(self.pool, EvalPool)):
            self.pool.quarantine = self.integrity.quarantine

    def _default_cache(self) -> EvalCache:
        """The cache __init__ semantics attach to a pool it builds itself:
        persisted in the workdir when there is one, in-memory otherwise."""
        return EvalCache(self.workdir / "eval_cache.jsonl"
                         if self.workdir else None)

    def _resolve_backend(self, backend, service, pool, workers,
                         eval_cache) -> EvalBackend:
        legacy = {k: v for k, v in dict(service=service, pool=pool,
                                        workers=workers,
                                        eval_cache=eval_cache).items()
                  if v is not _UNSET}
        if legacy and backend is not None:
            raise TypeError(
                f"pass either backend= or the deprecated kwargs "
                f"({', '.join(sorted(legacy))}), not both")
        if legacy:
            warnings.warn(
                f"KernelScientist({', '.join(k + '=' for k in sorted(legacy))}"
                f") is deprecated; pass a single backend= (an EvalBackend, "
                f"or an EvaluationService to wrap — e.g. "
                f"backend=EvalPool.of(service, workers=N, cache=...))",
                DeprecationWarning, stacklevel=3)
            pool = legacy.get("pool")
            if pool is None:
                cache = (self._default_cache()
                         if legacy.get("eval_cache", True) else None)
                pool = EvalPool.of(legacy.get("service")
                                   or EvaluationService(),
                                   workers=legacy.get("workers", 1),
                                   cache=cache,
                                   retry_policy=self.retry_policy,
                                   events=self.events, sleep=self._sleep)
            elif pool.events is None:
                pool.events = self.events
            return pool
        if backend is None:
            backend = EvaluationService()
        if isinstance(backend, EvalBackend):
            if getattr(backend, "events", _UNSET) is None:
                backend.events = self.events
            return backend
        if hasattr(backend, "submit"):
            return EvalPool.of(backend, workers=1,
                               cache=self._default_cache(),
                               retry_policy=self.retry_policy,
                               events=self.events, sleep=self._sleep)
        raise TypeError(
            f"backend must satisfy the EvalBackend protocol or be an "
            f"EvaluationService-like object with submit(); got "
            f"{type(backend).__name__}")

    # The first pool worker doubles as the legacy single-service view;
    # assigning a new service rebuilds the pool around it, preserving the
    # existing cache *instance* (a custom cache path survives even without
    # a workdir), retry policy, events, sleep, and worker count — dropping
    # to one worker if the new service can't clone.
    @property
    def service(self):
        return self.pool.services[0]

    @service.setter
    def service(self, svc) -> None:
        old = self.pool
        cache = getattr(old, "cache", None)
        if cache is None and not isinstance(old, EvalPool):
            # rebuilding around a foreign backend with no cache of its own:
            # fall back to the same default __init__ would attach
            cache = self._default_cache()
        n_workers = len(getattr(old, "services", ())) or 1
        workers = n_workers if hasattr(svc, "clone") else 1
        transport = getattr(getattr(old, "transport", None), "kind",
                            "inprocess")
        self.pool = EvalPool.of(
            svc, workers=workers, cache=cache,
            retry_policy=getattr(old, "retry_policy", self.retry_policy),
            events=getattr(old, "events", None) or self.events,
            sleep=getattr(old, "_sleep", self._sleep),
            transport=transport)
        self._wire_quarantine()
        if isinstance(old, EvalPool):
            old.close(wait=False)

    # ------------------------------------------------------------- resume
    @classmethod
    def resume(cls, workdir, llm: Optional[LLMClient] = None,
               backend=None, service=_UNSET,
               **kwargs) -> "KernelScientist":
        """Reconstruct a campaign from its workdir and continue it.

        Pass ``llm`` / ``backend`` instances constructed exactly as in the
        original run (same seeds and noise); their internal decision state is
        fast-forwarded from ``state.json`` so the continued campaign makes
        the same choices an uninterrupted run would have made.  If the last
        persisted state holds a partially-completed generation, the next
        :meth:`run` finishes it first — only the kernel that was in flight
        at the moment of the crash is re-generated and re-submitted.

        ``service=`` (and ``workers=`` / ``eval_cache=`` via ``kwargs``) are
        the deprecated pre-``EvalBackend`` spellings; ``__init__`` shims
        them with a ``DeprecationWarning``.
        """
        workdir = pathlib.Path(workdir)
        state_path = workdir / "state.json"
        if not state_path.exists():
            raise FileNotFoundError(
                f"no resumable campaign in {workdir} (state.json missing)")
        state = json.loads(state_path.read_text())
        if service is not _UNSET:
            kwargs["service"] = service
        sci = cls(llm=llm, backend=backend, workdir=workdir, **kwargs)
        if not state.get("seeded"):
            # crashed mid-seed: cheapest correct recovery is a fresh start
            sci.events.emit("resume", mode="restart_unseeded")
            return sci
        sci.population = Population.load(workdir / "population.json")
        logbook_path = workdir / "logbook.json"
        if logbook_path.exists():
            sci.logbook = [GenerationLog.from_dict(d)
                           for d in json.loads(logbook_path.read_text())]
        sci._seeded = True
        sci._restore_backend(sci.llm, state.get("llm"))
        sci.pool.load_state_dict(state.get("service"))
        if state.get("integrity"):
            sci.integrity.load_state_dict(state["integrity"])
        inflight = state.get("inflight")
        if inflight:
            inflight.setdefault("pending", [])
            # records whose evaluation completed ("submitted") or whose
            # writer output is durable ("pending" — source persisted, eval
            # to be re-enqueued) survive; anything else from the interrupted
            # generation is a ghost whose id is re-issued on replay
            durable = ({s[0] for s in inflight["submitted"]}
                       | set(inflight["pending"]))
            ghosts = [r.rid for r in sci.population
                      if r.generation == inflight["generation"]
                      and r.rid not in durable]
            for rid in ghosts:
                sci.population.remove(rid)
            sci._inflight = inflight
        sci.events.emit(
            "resume", mode="continue", generations_done=len(sci.logbook),
            population=len(sci.population),
            inflight_generation=(inflight["generation"] if inflight else None),
            inflight_submitted=(len(inflight["submitted"]) if inflight
                                else None),
            inflight_pending=(len(inflight["pending"]) if inflight else None))
        return sci

    @staticmethod
    def _restore_backend(obj, state) -> None:
        if state is not None and hasattr(obj, "load_state_dict"):
            obj.load_state_dict(state)

    # ------------------------------------------------------------ seeding
    def seed(self, genomes=(SEED_LIBRARY, SEED_NAIVE, SEED_MXU),
             descriptions=("library implementation (provided baseline)",
                           "direct translation into a Pallas kernel "
                           "(unoptimized: f32 math, per-tile dequant)",
                           "first working MXU kernel (128^3 VMEM tiles)"),
             ) -> None:
        """Paper §3: the process starts from a few seed kernels."""
        if len(self.population) != 0:
            raise RuntimeError("already seeded")
        self.events.emit("campaign_start", seeds=len(genomes))
        handles = []
        for genome, desc in zip(genomes, descriptions):
            source = codegen.render_source(genome, desc)
            rec = KernelRecord(
                rid=self.population.new_id(), parents=(), source=source,
                genome=genome,
                experiment={"description": desc, "rubric": "(seed)",
                            "performance": [0, 0], "innovation": 0},
                writer_report="(seed kernel)", generation=0)
            self.population.add(rec)
            handles.append((rec, self._submit_record(source, tag=rec.rid)))
        for rec, handle in handles:   # seeds evaluate concurrently
            self._apply_handle(rec, handle)
            self._persist()
        self._seeded = True
        self._persist()
        self.events.emit("seeded", population=len(self.population))

    # --------------------------------------------------------------- loop
    def run_generation(self, generation: int) -> GenerationLog:
        self.events.emit("generation_start", generation=generation)
        sel = self._stage(
            "selector", generation,
            lambda: selector.select(self.population, self.llm,
                                    self.task_text),
            fallback=lambda: selector.fallback_select(self.population))
        plans = self._stage(
            "designer", generation,
            lambda: designer.design(self.population, sel.basis_code,
                                    sel.basis_reference, self.llm,
                                    self.task_text),
            fallback=lambda: designer.fallback_design(self.population,
                                                      sel.basis_code))
        picked = designer.pick3(plans)
        inflight = {"generation": generation,
                    "selection": dataclasses.asdict(sel),
                    "plans": plans, "picked": picked, "submitted": [],
                    "pending": []}
        self._persist(inflight)
        return self._finish_generation(inflight)

    def _finish_generation(self, inflight: dict) -> GenerationLog:
        """Run (or, after a resume, complete) the submission half of a
        generation from its persisted in-flight checkpoint.

        The writer stage overlaps with in-flight evaluations: each writer
        output is enqueued on the pool the moment it exists (recorded as
        ``pending``), then results are applied and persisted in record-id
        order, so the durable ``submitted`` list is identical whatever
        order the pool completes them in."""
        generation = inflight["generation"]
        sel = selector.Selection(**inflight["selection"])
        picked = inflight["picked"]
        submitted = [tuple(s) for s in inflight["submitted"]]
        pending = list(inflight.get("pending", []))

        handles: dict[str, object] = {}
        for rid in pending:
            # resumed mid-drain: the writer output is durable — re-enqueue
            # its evaluation (a duplicate whose verdict already landed in
            # the cache returns without consuming a platform slot)
            handles[rid] = self._submit_record(
                self.population.get(rid).source, tag=rid)

        for exp in picked[len(submitted) + len(pending):]:
            # three independent writer instances (paper §3.2); each service
            # still serialises its own submissions — the pool is what scales
            rec = self._write_experiment(generation, sel, exp)
            pending.append(rec.rid)
            inflight["pending"] = list(pending)
            self._persist(inflight)
            handles[rec.rid] = self._submit_record(rec.source, tag=rec.rid)

        for rid in sorted(handles):   # apply in submission order
            rec = self.population.get(rid)
            self._apply_handle(rec, handles[rid])
            pending.remove(rid)
            submitted.append((rec.rid, rec.status,
                              rec.score if rec.score != float("inf")
                              else None))
            inflight["submitted"] = [list(s) for s in submitted]
            inflight["pending"] = list(pending)
            self._persist(inflight)

        remeasured = self._run_canaries(generation, handles)
        if remeasured:
            # drifted-worker verdicts were re-measured: refresh the
            # generation's submitted tuples from the trusted records
            submitted = [
                (rid, self.population.get(rid).status,
                 self.population.get(rid).score
                 if self.population.get(rid).score != float("inf") else None)
                for (rid, _, _) in submitted]
            inflight["submitted"] = [list(s) for s in submitted]
            self._persist(inflight)

        best = self.population.best()
        log = GenerationLog(
            generation=generation,
            selection=inflight["selection"],
            plans=[{k: p[k] for k in ("description", "performance",
                                      "innovation")}
                   for p in inflight["plans"]],
            picked=[p["description"] for p in picked],
            submitted=submitted,
            best_rid=best.rid if best else "",
            best_geomean_us=best.score if best else float("inf"))
        self.logbook.append(log)
        self._persist()   # clears the in-flight checkpoint
        self.events.emit(
            "generation_end", generation=generation, best_rid=log.best_rid,
            best_geomean_us=(None if log.best_geomean_us == float("inf")
                             else round(log.best_geomean_us, 3)))
        if self.integrity.health is not None:
            self.integrity.health.snapshot(
                self.events, generation=generation,
                population=len(self.population),
                submissions=getattr(self.pool, "submissions", None),
                best_geomean_us=(None if log.best_geomean_us == float("inf")
                                 else round(log.best_geomean_us, 3)))
        return log

    def _write_experiment(self, generation: int, sel, exp: dict
                          ) -> KernelRecord:
        """Writer stage only — the record joins the population as
        ``pending``; its evaluation is the caller's to enqueue."""
        wk = self._stage(
            "writer", generation,
            lambda: writer.write(self.population, sel.basis_code,
                                 sel.basis_reference, exp, self.llm,
                                 self.task_text),
            fallback=lambda: writer.fallback_write(self.population,
                                                   sel.basis_code, exp))
        rec = KernelRecord(
            rid=self.population.new_id(),
            parents=(sel.basis_code, sel.basis_reference),
            source=wk.source,
            genome=(KernelGenome.from_json(wk.genome_json)
                    if wk.genome_json else None),
            experiment={k: exp.get(k) for k in
                        ("description", "rubric", "performance",
                         "innovation")},
            writer_report=wk.report, generation=generation)
        self.population.add(rec)
        return rec

    def run(self, generations: int) -> Optional[KernelRecord]:
        if self.integrity.health is not None:
            self.integrity.health.start()
        remaining = generations
        if len(self.population) == 0 and self._inflight is None:
            self.seed()
        if self._inflight is not None and remaining > 0:
            inflight, self._inflight = self._inflight, None
            self._finish_generation(inflight)
            remaining -= 1
        start = len(self.logbook) + 1
        for g in range(start, start + remaining):
            # budgets are checked at generation boundaries only: the
            # campaign stops cleanly with everything persisted, never
            # mid-drain, and a resumed run re-checks before continuing
            if self._budget_stop(g):
                break
            self.run_generation(g)
        return self.population.best()

    def _budget_stop(self, generation: int) -> bool:
        health = self.integrity.health
        if health is None:
            return False
        reason = health.budget_exceeded(
            getattr(self.pool, "submissions", 0) or 0)
        if reason is None:
            return False
        self.events.emit("budget_stop", generation=generation, reason=reason,
                         elapsed_s=round(health.elapsed_s, 3))
        self._persist()
        return True

    # ------------------------------------------------------------ helpers
    def _stage(self, stage: str, generation: int, fn, fallback=None):
        """Run one LLM stage under the retry policy; fall back to the
        deterministic rule-based decision if it stays broken.

        With an LLM circuit breaker configured (``core.integrity``), a
        stage whose dependency is presumed down skips the whole retry/
        backoff schedule and goes straight to the fallback; the call that
        ends the breaker's cooldown is admitted as the half-open probe."""
        self.events.emit("stage_start", stage=stage, generation=generation)
        t0 = time.perf_counter()
        brk = self.integrity.llm_breaker

        if brk is not None and not brk.allow():
            e = CircuitOpenError(
                f"LLM circuit open ({brk.failures} consecutive stage "
                f"failures); using the rule-based fallback")
            self.events.emit("breaker", name="llm", action="skip",
                             state=brk.state, stage=stage,
                             generation=generation)
            if fallback is None:
                self.events.emit("stage_end", stage=stage,
                                 generation=generation, status="error",
                                 error=_errtext(e), duration_s=round(
                                     time.perf_counter() - t0, 6))
                raise e
            self.events.emit("fallback", stage=stage, generation=generation,
                             error=_errtext(e))
            out = fallback()
            self.events.emit("stage_end", stage=stage, generation=generation,
                             status="fallback",
                             duration_s=round(time.perf_counter() - t0, 6))
            return out

        def on_retry(attempt, exc, delay):
            self.events.emit("retry", stage=stage, generation=generation,
                             attempt=attempt, error=_errtext(exc),
                             delay_s=round(delay, 3))

        status = "ok"
        try:
            out = resilience.retry_call(fn, policy=self.retry_policy,
                                        on_retry=on_retry, sleep=self._sleep)
            if brk is not None:
                self._breaker_record(brk, success=True, stage=stage,
                                     generation=generation)
        except Exception as e:
            if brk is not None:
                self._breaker_record(brk, success=False, stage=stage,
                                     generation=generation)
            if fallback is None:
                self.events.emit("stage_end", stage=stage,
                                 generation=generation, status="error",
                                 error=_errtext(e), duration_s=round(
                                     time.perf_counter() - t0, 6))
                raise
            self.events.emit("fallback", stage=stage, generation=generation,
                             error=_errtext(e))
            out = fallback()
            status = "fallback"
        self.events.emit("stage_end", stage=stage, generation=generation,
                         status=status,
                         duration_s=round(time.perf_counter() - t0, 6))
        return out

    def _breaker_record(self, brk, success: bool, **fields) -> None:
        prev = brk.state
        brk.record_success() if success else brk.record_failure()
        if brk.state != prev:
            self.events.emit("breaker", name=brk.name,
                             transition=f"{prev}->{brk.state}", **fields)

    def _submit_record(self, source: str, tag,
                       priority: int = None) -> EvalHandle:
        """Submit through the eval circuit breaker (when configured): an
        open breaker refuses the submission up front with a pre-failed
        handle, so the drain marks the record ``failed`` without paying the
        pool's retry schedule against a dead backend."""
        brk = self.integrity.eval_breaker
        if brk is not None and not brk.allow():
            self.events.emit("breaker", name="eval", action="skip",
                             state=brk.state, tag=tag)
            handle = EvalHandle(EvalCache.key_of(source), tag=tag)
            handle._finish(exc=CircuitOpenError(
                f"evaluation circuit open ({brk.failures} consecutive "
                f"submission failures)"))
            return handle
        if priority is None:
            return self.pool.submit_async(source, tag=tag)
        return self.pool.submit_async(source, priority=priority, tag=tag)

    def _apply_handle(self, rec: KernelRecord, handle) -> None:
        """Block on one pooled evaluation, audit its verdict, and apply the
        trusted outcome.  A submission the platform never accepts (retries
        exhausted inside the pool worker) is marked ``failed`` rather than
        left ``pending``, so a resumed campaign carries no ghost members.
        BaseExceptions (KeyboardInterrupt — a killed campaign) propagate."""
        brk = self.integrity.eval_breaker
        try:
            res = handle.result()
        except Exception as e:
            # a refused (circuit-open) submission is not new evidence about
            # the backend — only real failures feed the breaker
            if brk is not None and not isinstance(e, CircuitOpenError):
                self._breaker_record(brk, success=False, tag=rec.rid)
            rec.status = "failed"
            rec.error = _errtext(e)
            self.events.emit("eval_result", rid=rec.rid, status="failed",
                             error=rec.error, cached=handle.cached,
                             duration_s=round(handle.duration_s, 6))
            return
        if brk is not None:
            self._breaker_record(brk, success=True, tag=rec.rid)
        res = self._audit(rec, res)
        self._apply_eval(rec, res)
        self.events.emit(
            "eval_result", rid=rec.rid, status=rec.status,
            geomean_us=(None if rec.score == float("inf")
                        else round(rec.score, 3)),
            cached=handle.cached,
            duration_s=round(handle.duration_s, 6))

    def _apply_eval(self, rec: KernelRecord, res: EvalResult) -> None:
        rec.status = res.status
        rec.error = res.error
        rec.timings_us = dict(res.timings_us)

    # -------------------------------------------------- verdict integrity
    def _audit(self, rec: KernelRecord, res: EvalResult) -> EvalResult:
        """Gate one ``ok`` verdict through the timing auditor before it may
        update the population.  A flagged verdict triggers the quorum:
        ``quorum_k`` salted resubmissions of the same kernel (urgent
        priority — the drain is blocked on this record), merged by robust
        median.  Entirely content-keyed, so the audit replays identically
        across workers counts, transports, and kill/resume (completed
        samples return as cache hits)."""
        auditor = self.integrity.auditor
        if auditor is None or res.status != "ok" or not res.timings_us:
            return res
        g = geomean(res.timings_us.values())
        reason = auditor.flag(g, self._trusted_baseline(rec))
        if reason is None:
            return res
        auditor.flags += 1
        self.events.emit("audit_flag", rid=rec.rid, geomean_us=round(g, 3),
                         reason=reason)
        sample_handles = [
            self._submit_record(auditor.salted(rec.source, i),
                                tag=f"{rec.rid}/quorum{i}",
                                priority=PRIORITY_URGENT)
            for i in range(1, auditor.quorum_k + 1)]
        samples = []
        for h in sample_handles:
            try:
                samples.append(h.result())
            except Exception:
                samples.append(None)   # a lost sample shrinks the quorum
        final, corrected = auditor.merge(res, samples)
        self.events.emit(
            "audit_quorum", rid=rec.rid, corrected=corrected,
            samples=sum(1 for s in samples
                        if s is not None and s.status == "ok"),
            geomean_us=round(g, 3),
            final_geomean_us=(round(geomean(final.timings_us.values()), 3)
                              if final.timings_us else None))
        return final

    def _trusted_baseline(self, rec: KernelRecord) -> Optional[float]:
        """Geomean of the nearest ok ancestor — the lineage expectation the
        auditor's z-test compares a fresh verdict against.  Breadth-first
        up the parent links (deterministic: parents tuples are ordered);
        ``None`` for seeds and orphans, which are therefore always
        re-measured before being trusted."""
        seen = set()
        frontier = list(rec.parents)
        while frontier:
            rid, frontier = frontier[0], frontier[1:]
            if rid in seen:
                continue
            seen.add(rid)
            try:
                anc = self.population.get(rid)
            except KeyError:
                continue
            if anc.status == "ok" and anc.timings_us:
                return geomean(anc.timings_us.values())
            frontier.extend(anc.parents)
        return None

    def _run_canaries(self, generation: int, handles: dict) -> list:
        """Generation-end drift sweep: run the known-timing sentinel on
        every worker directly (bypassing queue + cache), compare against
        the campaign reference, and respond to drift by respawning the
        worker and re-measuring every record it evaluated this generation.
        Returns the re-measured record ids."""
        canary = self.integrity.canary
        if (canary is None or not canary.due(generation)
                or not isinstance(self.pool, EvalPool)):
            return []
        sentinel = canary.sentinel_source()
        remeasured = []
        for idx in range(self.pool.transport.num_workers):
            try:
                res = self.pool.run_direct(idx, sentinel)
                g = (geomean(res.timings_us.values())
                     if res.status == "ok" and res.timings_us else None)
            except Exception as e:
                self.events.emit("canary", generation=generation, worker=idx,
                                 error=_errtext(e))
                g = None
            verdict = canary.check(g)
            self.events.emit(
                "canary", generation=generation, worker=idx, verdict=verdict,
                geomean_us=(round(g, 3) if g is not None else None),
                reference_us=(round(canary.reference_us, 3)
                              if canary.reference_us is not None else None))
            if verdict != "drift":
                continue
            self.events.emit("worker_drift", generation=generation,
                             worker=idx,
                             geomean_us=(round(g, 3) if g is not None
                                         else None),
                             reference_us=round(canary.reference_us or 0, 3))
            self.pool.respawn_worker(idx)
            # nothing this worker measured in this generation can be
            # trusted: drop the cached verdicts and re-measure urgently
            affected = sorted(
                rid for rid, h in handles.items()
                if getattr(h, "worker", None) == idx and not h.cached)
            for rid in affected:
                rec = self.population.get(rid)
                if self.pool.cache is not None:
                    self.pool.cache.invalidate(EvalCache.key_of(rec.source))
                self.events.emit("verdict_invalidated", rid=rid, worker=idx,
                                 generation=generation)
                fresh = self._submit_record(rec.source, tag=rid,
                                            priority=PRIORITY_URGENT)
                self._apply_handle(rec, fresh)
                self._persist()
                remeasured.append(rid)
        return remeasured

    def _backend_state(self, obj) -> Optional[dict]:
        sd = getattr(obj, "state_dict", None)
        return sd() if sd else None

    def _persist(self, inflight: Optional[dict] = None) -> None:
        if not self.workdir:
            return
        # population first, state.json last: state.json only ever references
        # records that are already durable, so any crash window resolves to
        # "replay the in-flight kernel"
        self.population.save(self.workdir / "population.json")
        tmp = self.workdir / "logbook.json.tmp"
        tmp.write_text(json.dumps([l.to_dict() for l in self.logbook],
                                  indent=1))
        tmp.replace(self.workdir / "logbook.json")
        state = {"schema": _STATE_SCHEMA,
                 "seeded": self._seeded,
                 "llm": self._backend_state(self.llm),
                 "service": self.pool.state_dict(),
                 "integrity": (self.integrity.state_dict()
                               if self.integrity.enabled else None),
                 "inflight": inflight}
        tmp = self.workdir / "state.json.tmp"
        tmp.write_text(json.dumps(state, indent=1))
        tmp.replace(self.workdir / "state.json")

    # ------------------------------------------------------------- report
    def trajectory(self) -> list:
        """(generation, best_geomean_us) pairs — the discovery curve.

        ``None`` (not the non-JSON token ``Infinity``) stands in for "no
        successful kernel yet"."""
        out = []
        best = min((r.score for r in self.population if r.generation == 0),
                   default=float("inf"))
        out.append((0, best if best != float("inf") else None))
        for log in self.logbook:
            best = min(best, log.best_geomean_us)
            out.append((log.generation,
                        best if best != float("inf") else None))
        return out
