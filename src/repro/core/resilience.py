"""Campaign resilience: retry/backoff policies and seeded fault injection.

The paper's loop ran autonomously for days against a *shared external
evaluation queue* (§3.4): submissions were processed sequentially by a remote
platform with variable queueing delays, transient API failures, and
occasionally malformed LLM replies.  Surviving that environment — rather than
aborting a multi-day campaign on the first hiccup — is part of the method.
This module supplies the two halves needed to reproduce it offline:

* ``RetryPolicy`` / ``retry_call`` — bounded retry with exponential backoff
  and deterministic jitter, plus an optional per-attempt timeout.  Knob →
  paper §3.4 mapping:

  - ``max_attempts``  — how many times a stage re-asks the LLM or re-submits
    to the evaluation queue before the scientist falls back to a rule-based
    decision (the paper's loop "waited and retried" on platform errors).
  - ``base_delay_s`` / ``multiplier`` / ``max_delay_s`` — exponential backoff
    between attempts, modelling the "good citizen" pacing against the shared
    sequential queue (§3.4: one submission in flight at a time).
  - ``jitter`` — deterministic (seed + attempt hashed) spread of the backoff
    so many campaigns do not thunder the queue in lockstep.
  - ``timeout_s`` — per-attempt wall-clock bound, modelling the variable and
    occasionally unbounded evaluation-queue delays; a timed-out attempt is
    retried like any transient failure.  (Implemented with a worker thread;
    an abandoned attempt may keep running in the background — acceptable for
    network calls, so the default is ``None`` for in-process backends.)

* ``FlakyLLM`` / ``FlakyService`` — seeded fault-injection decorators that
  wrap an ``LLMClient`` / ``EvaluationService`` and deterministically inject
  transient errors, timeouts, and malformed (non-JSON) replies *without*
  consuming the wrapped backend's state.  They make every resilience path in
  ``KernelScientist`` testable in this offline container; a given
  ``(seed, call_index)`` pair always produces the same fault, so soak tests
  are exactly reproducible.
"""
from __future__ import annotations

import dataclasses
import hashlib
import os
import time
from typing import Callable, Optional


class TransientError(RuntimeError):
    """A failure worth retrying: dropped connection, HTTP 5xx, queue hiccup."""


class ServiceBusyError(TransientError):
    """An evaluation worker is occupied by another in-flight submission.

    Distinct from a real platform fault: the submission never reached the
    platform, the worker is simply busy, so the right response is to reroute
    (resubmit immediately, ideally to a different worker) rather than to
    back off exponentially.  ``RetryPolicy.no_backoff`` encodes exactly
    that: ``retry_call`` retries these with zero delay."""


#: Exception types that ``retry_call`` retries by default.  ``ValueError`` and
#: ``KeyError`` cover malformed LLM replies (bad JSON, missing schema fields);
#: ``TimeoutError`` covers per-attempt timeouts; ``ConnectionError`` / OSError
#: cover the network failures an HTTP backend raises.
DEFAULT_RETRYABLE = (TransientError, TimeoutError, ValueError, KeyError,
                     ConnectionError, OSError)


@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    max_attempts: int = 4
    base_delay_s: float = 0.5
    multiplier: float = 2.0
    max_delay_s: float = 30.0
    jitter: float = 0.25          # +- fraction of the delay, deterministic
    timeout_s: Optional[float] = None
    retryable: tuple = DEFAULT_RETRYABLE
    #: Exception types retried with *zero* delay: the failure means "worker
    #: occupied, reroute now", not "platform unhealthy, back off".
    no_backoff: tuple = (ServiceBusyError,)
    seed: int = 0

    def delay(self, attempt: int) -> float:
        """Backoff before retry number ``attempt`` (1-based), deterministic."""
        d = min(self.base_delay_s * self.multiplier ** (attempt - 1),
                self.max_delay_s)
        if self.jitter:
            d *= 1.0 + self.jitter * _unit(self.seed, "delay", attempt)
        return max(d, 0.0)


#: Sensible production default (~0.5s, 1s, 2s between 4 attempts).
DEFAULT_POLICY = RetryPolicy()

#: For tests and offline ScriptedLLM runs: same attempt budget, no waiting.
NO_WAIT_POLICY = RetryPolicy(base_delay_s=0.0, jitter=0.0)


def _unit(*parts) -> float:
    """Deterministic pseudo-random float in [-1, 1] from the hashed parts."""
    h = hashlib.sha256(":".join(str(p) for p in parts).encode()).digest()
    return int.from_bytes(h[:8], "big") / 2 ** 63 - 1.0


def _uniform01(*parts) -> float:
    """Deterministic pseudo-random float in [0, 1) from the hashed parts."""
    h = hashlib.sha256(":".join(str(p) for p in parts).encode()).digest()
    return int.from_bytes(h[:8], "big") / 2 ** 64


def _call_with_timeout(fn: Callable, timeout_s: Optional[float]):
    if not timeout_s:
        return fn()
    import concurrent.futures
    ex = concurrent.futures.ThreadPoolExecutor(max_workers=1)
    fut = ex.submit(fn)
    try:
        return fut.result(timeout=timeout_s)
    except concurrent.futures.TimeoutError:
        raise TimeoutError(f"attempt exceeded the {timeout_s}s stage timeout")
    finally:
        ex.shutdown(wait=False)


def retry_call(fn: Callable, policy: RetryPolicy = DEFAULT_POLICY,
               on_retry: Optional[Callable] = None,
               sleep: Callable = time.sleep):
    """Call ``fn()`` under ``policy``; return its result.

    Retryable exceptions are swallowed up to ``policy.max_attempts`` total
    attempts with exponential backoff between them; the last one is re-raised.
    Non-retryable exceptions (and BaseExceptions such as KeyboardInterrupt)
    propagate immediately.  ``on_retry(attempt, exc, delay_s)`` is invoked
    before each backoff so callers can log retries.
    """
    if policy.max_attempts < 1:
        raise ValueError("max_attempts must be >= 1")
    for attempt in range(1, policy.max_attempts + 1):
        try:
            return _call_with_timeout(fn, policy.timeout_s)
        except policy.retryable as e:
            if attempt == policy.max_attempts:
                raise
            delay = (0.0 if isinstance(e, policy.no_backoff)
                     else policy.delay(attempt))
            if on_retry is not None:
                on_retry(attempt, e, delay)
            if delay:
                sleep(delay)


# ---------------------------------------------------------------------------
# Seeded fault injection
# ---------------------------------------------------------------------------
_MALFORMED_REPLY = ("I could not produce the requested JSON this time — "
                    "here is a prose apology instead. (injected fault: "
                    "malformed LLM reply)")


class FlakyLLM:
    """Wrap an ``LLMClient`` and deterministically inject transient faults.

    Per call, one uniform draw keyed on ``(seed, call_index)`` selects the
    fault: ``TransientError`` with probability ``error_rate``, ``TimeoutError``
    with ``timeout_rate``, a malformed non-JSON reply with ``malformed_rate``,
    otherwise the wrapped client answers.  Faults fire *before* the wrapped
    client is consulted, so its internal call counter only advances on the
    attempts that actually reach it.
    """

    def __init__(self, inner, seed: int = 0, error_rate: float = 0.1,
                 timeout_rate: float = 0.0, malformed_rate: float = 0.0):
        if error_rate + timeout_rate + malformed_rate > 1.0:
            raise ValueError("fault rates must sum to <= 1")
        self.inner = inner
        self.seed = seed
        self.error_rate = error_rate
        self.timeout_rate = timeout_rate
        self.malformed_rate = malformed_rate
        self.calls = 0
        self.faults = 0

    def complete(self, prompt: str) -> str:
        self.calls += 1
        u = _uniform01(self.seed, "llm", self.calls)
        if u < self.error_rate:
            self.faults += 1
            raise TransientError(
                f"injected: LLM API returned HTTP 503 (call {self.calls})")
        if u < self.error_rate + self.timeout_rate:
            self.faults += 1
            raise TimeoutError(
                f"injected: LLM API stalled past the deadline "
                f"(call {self.calls})")
        if u < self.error_rate + self.timeout_rate + self.malformed_rate:
            self.faults += 1
            return _MALFORMED_REPLY
        return self.inner.complete(prompt)

    # resumable-campaign state (see KernelScientist.resume)
    def state_dict(self) -> dict:
        inner = getattr(self.inner, "state_dict", None)
        return {"calls": self.calls, "faults": self.faults,
                "inner": inner() if inner else None}

    def load_state_dict(self, d: dict) -> None:
        self.calls = d["calls"]
        self.faults = d.get("faults", 0)
        if d.get("inner") is not None:
            self.inner.load_state_dict(d["inner"])


class FlakyService:
    """Wrap an ``EvaluationService`` and inject transient submission failures.

    Models the shared evaluation queue dropping or timing out a submission
    (paper §3.4) before it reaches the platform: the wrapped service's
    submission counter does not advance on an injected fault, exactly like a
    request that never arrived.
    """

    def __init__(self, inner, seed: int = 0, error_rate: float = 0.1,
                 timeout_rate: float = 0.0):
        if error_rate + timeout_rate > 1.0:
            raise ValueError("fault rates must sum to <= 1")
        self.inner = inner
        self.seed = seed
        self.error_rate = error_rate
        self.timeout_rate = timeout_rate
        self.calls = 0
        self.faults = 0

    def submit(self, source: str):
        self.calls += 1
        u = _uniform01(self.seed, "svc", self.calls)
        if u < self.error_rate:
            self.faults += 1
            raise TransientError(
                f"injected: evaluation queue dropped the submission "
                f"(call {self.calls})")
        if u < self.error_rate + self.timeout_rate:
            self.faults += 1
            raise TimeoutError(
                f"injected: evaluation queue exceeded its deadline "
                f"(call {self.calls})")
        return self.inner.submit(source)

    def state_dict(self) -> dict:
        inner = getattr(self.inner, "state_dict", None)
        return {"calls": self.calls, "faults": self.faults,
                "inner": inner() if inner else None}

    def load_state_dict(self, d: dict) -> None:
        self.calls = d["calls"]
        self.faults = d.get("faults", 0)
        if d.get("inner") is not None:
            self.inner.load_state_dict(d["inner"])

    def clone(self) -> "FlakyService":
        """An independent worker for ``EvalPool.of``: same platform (the
        inner service clones with an identical timing seed) but a distinct
        fault stream, as two routes into a shared queue would fail
        independently.  Chained cloning (clone of a clone) steps the fault
        seed again, giving every pool worker its own stream."""
        return FlakyService(self.inner.clone(), seed=self.seed + 1,
                            error_rate=self.error_rate,
                            timeout_rate=self.timeout_rate)

    def service_spec(self) -> dict:
        """JSON spec so a subprocess worker rebuilds this wrapper stack
        (``eval_worker.build_service``) with identical seeds and rates."""
        from .transport import service_spec_of
        return {"kind": "flaky", "inner": service_spec_of(self.inner),
                "seed": self.seed, "error_rate": self.error_rate,
                "timeout_rate": self.timeout_rate}

    def __getattr__(self, name):
        # delegate everything else (submissions, bench_configs, ...) so the
        # wrapper is a drop-in EvaluationService
        return getattr(self.inner, name)


class CrashService:
    """Wrap an ``EvaluationService`` and deterministically *kill the whole
    worker process* mid-benchmark — the fault class that distinguishes a
    distributed campaign from a threaded one: a segfaulting kernel, an OOM
    kill, a preempted host.

    ``os._exit`` (no cleanup, no Python unwinding) models a hard death; the
    draw is keyed on ``(seed, incarnation, call_index)``, so a respawned
    worker (stepped incarnation — ``SubprocessTransport`` passes it through
    ``eval_worker.build_service``) faces a fresh fault stream and the
    resubmitted job eventually passes rather than crash-looping at the same
    call forever.  Only meaningful inside a subprocess worker: in-process it
    would take the campaign (or the test runner) down with it, which is
    exactly the failure mode the subprocess transport exists to contain.
    """

    def __init__(self, inner, seed: int = 0, crash_rate: float = 0.1,
                 incarnation: int = 0):
        if not 0.0 <= crash_rate <= 1.0:
            raise ValueError("crash_rate must be in [0, 1]")
        self.inner = inner
        self.seed = seed
        self.crash_rate = crash_rate
        self.incarnation = incarnation
        self.calls = 0

    def submit(self, source: str):
        self.calls += 1
        u = _uniform01(self.seed, "kill", self.incarnation, self.calls)
        if u < self.crash_rate:
            os._exit(17)          # hard worker death, mid-benchmark
        return self.inner.submit(source)

    def clone(self) -> "CrashService":
        return CrashService(self.inner.clone(), seed=self.seed + 1,
                            crash_rate=self.crash_rate,
                            incarnation=self.incarnation)

    def service_spec(self) -> dict:
        from .transport import service_spec_of
        return {"kind": "crash", "inner": service_spec_of(self.inner),
                "seed": self.seed, "crash_rate": self.crash_rate}

    def __getattr__(self, name):
        return getattr(self.inner, name)
