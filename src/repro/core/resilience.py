"""Campaign resilience: retry/backoff policies and seeded fault injection.

The paper's loop ran autonomously for days against a *shared external
evaluation queue* (§3.4): submissions were processed sequentially by a remote
platform with variable queueing delays, transient API failures, and
occasionally malformed LLM replies.  Surviving that environment — rather than
aborting a multi-day campaign on the first hiccup — is part of the method.
This module supplies the two halves needed to reproduce it offline:

* ``RetryPolicy`` / ``retry_call`` — bounded retry with exponential backoff
  and deterministic jitter, plus an optional per-attempt timeout.  Knob →
  paper §3.4 mapping:

  - ``max_attempts``  — how many times a stage re-asks the LLM or re-submits
    to the evaluation queue before the scientist falls back to a rule-based
    decision (the paper's loop "waited and retried" on platform errors).
  - ``base_delay_s`` / ``multiplier`` / ``max_delay_s`` — exponential backoff
    between attempts, modelling the "good citizen" pacing against the shared
    sequential queue (§3.4: one submission in flight at a time).
  - ``jitter`` — deterministic (seed + attempt hashed) spread of the backoff
    so many campaigns do not thunder the queue in lockstep.
  - ``timeout_s`` — per-attempt wall-clock bound, modelling the variable and
    occasionally unbounded evaluation-queue delays; a timed-out attempt is
    retried like any transient failure.  (Implemented with a worker thread;
    an abandoned attempt may keep running in the background — acceptable for
    network calls, so the default is ``None`` for in-process backends.)

* ``FlakyLLM`` / ``FlakyService`` — seeded fault-injection decorators that
  wrap an ``LLMClient`` / ``EvaluationService`` and deterministically inject
  transient errors, timeouts, and malformed (non-JSON) replies *without*
  consuming the wrapped backend's state.  They make every resilience path in
  ``KernelScientist`` testable in this offline container; a given
  ``(seed, call_index)`` pair always produces the same fault, so soak tests
  are exactly reproducible.
"""
from __future__ import annotations

import dataclasses
import hashlib
import os
import time
from typing import Callable, Optional


class TransientError(RuntimeError):
    """A failure worth retrying: dropped connection, HTTP 5xx, queue hiccup."""


class ServiceBusyError(TransientError):
    """An evaluation worker is occupied by another in-flight submission.

    Distinct from a real platform fault: the submission never reached the
    platform, the worker is simply busy, so the right response is to reroute
    (resubmit immediately, ideally to a different worker) rather than to
    back off exponentially.  ``RetryPolicy.no_backoff`` encodes exactly
    that: ``retry_call`` retries these with zero delay."""


#: Exception types that ``retry_call`` retries by default.  ``ValueError`` and
#: ``KeyError`` cover malformed LLM replies (bad JSON, missing schema fields);
#: ``TimeoutError`` covers per-attempt timeouts; ``ConnectionError`` / OSError
#: cover the network failures an HTTP backend raises.
DEFAULT_RETRYABLE = (TransientError, TimeoutError, ValueError, KeyError,
                     ConnectionError, OSError)


@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    max_attempts: int = 4
    base_delay_s: float = 0.5
    multiplier: float = 2.0
    max_delay_s: float = 30.0
    jitter: float = 0.25          # +- fraction of the delay, deterministic
    timeout_s: Optional[float] = None
    retryable: tuple = DEFAULT_RETRYABLE
    #: Exception types retried with *zero* delay: the failure means "worker
    #: occupied, reroute now", not "platform unhealthy, back off".
    no_backoff: tuple = (ServiceBusyError,)
    seed: int = 0

    def delay(self, attempt: int) -> float:
        """Backoff before retry number ``attempt`` (1-based), deterministic."""
        d = min(self.base_delay_s * self.multiplier ** (attempt - 1),
                self.max_delay_s)
        if self.jitter:
            d *= 1.0 + self.jitter * _unit(self.seed, "delay", attempt)
        return max(d, 0.0)


#: Sensible production default (~0.5s, 1s, 2s between 4 attempts).
DEFAULT_POLICY = RetryPolicy()

#: For tests and offline ScriptedLLM runs: same attempt budget, no waiting.
NO_WAIT_POLICY = RetryPolicy(base_delay_s=0.0, jitter=0.0)


def _unit(*parts) -> float:
    """Deterministic pseudo-random float in [-1, 1] from the hashed parts."""
    h = hashlib.sha256(":".join(str(p) for p in parts).encode()).digest()
    return int.from_bytes(h[:8], "big") / 2 ** 63 - 1.0


def _uniform01(*parts) -> float:
    """Deterministic pseudo-random float in [0, 1) from the hashed parts."""
    h = hashlib.sha256(":".join(str(p) for p in parts).encode()).digest()
    return int.from_bytes(h[:8], "big") / 2 ** 64


def _call_with_timeout(fn: Callable, timeout_s: Optional[float]):
    if not timeout_s:
        return fn()
    import concurrent.futures
    ex = concurrent.futures.ThreadPoolExecutor(max_workers=1)
    fut = ex.submit(fn)
    try:
        return fut.result(timeout=timeout_s)
    except concurrent.futures.TimeoutError:
        raise TimeoutError(f"attempt exceeded the {timeout_s}s stage timeout")
    finally:
        ex.shutdown(wait=False)


def retry_call(fn: Callable, policy: RetryPolicy = DEFAULT_POLICY,
               on_retry: Optional[Callable] = None,
               sleep: Callable = time.sleep):
    """Call ``fn()`` under ``policy``; return its result.

    Retryable exceptions are swallowed up to ``policy.max_attempts`` total
    attempts with exponential backoff between them; the last one is re-raised.
    Non-retryable exceptions (and BaseExceptions such as KeyboardInterrupt)
    propagate immediately.  ``on_retry(attempt, exc, delay_s)`` is invoked
    before each backoff so callers can log retries.
    """
    if policy.max_attempts < 1:
        raise ValueError("max_attempts must be >= 1")
    for attempt in range(1, policy.max_attempts + 1):
        try:
            return _call_with_timeout(fn, policy.timeout_s)
        except policy.retryable as e:
            if attempt == policy.max_attempts:
                raise
            delay = (0.0 if isinstance(e, policy.no_backoff)
                     else policy.delay(attempt))
            if on_retry is not None:
                on_retry(attempt, e, delay)
            if delay:
                sleep(delay)


# ---------------------------------------------------------------------------
# Seeded fault injection
# ---------------------------------------------------------------------------
_MALFORMED_REPLY = ("I could not produce the requested JSON this time — "
                    "here is a prose apology instead. (injected fault: "
                    "malformed LLM reply)")


class FlakyLLM:
    """Wrap an ``LLMClient`` and deterministically inject transient faults.

    Per call, one uniform draw keyed on ``(seed, call_index)`` selects the
    fault: ``TransientError`` with probability ``error_rate``, ``TimeoutError``
    with ``timeout_rate``, a malformed non-JSON reply with ``malformed_rate``,
    otherwise the wrapped client answers.  Faults fire *before* the wrapped
    client is consulted, so its internal call counter only advances on the
    attempts that actually reach it.
    """

    def __init__(self, inner, seed: int = 0, error_rate: float = 0.1,
                 timeout_rate: float = 0.0, malformed_rate: float = 0.0):
        if error_rate + timeout_rate + malformed_rate > 1.0:
            raise ValueError("fault rates must sum to <= 1")
        self.inner = inner
        self.seed = seed
        self.error_rate = error_rate
        self.timeout_rate = timeout_rate
        self.malformed_rate = malformed_rate
        self.calls = 0
        self.faults = 0

    def complete(self, prompt: str) -> str:
        self.calls += 1
        u = _uniform01(self.seed, "llm", self.calls)
        if u < self.error_rate:
            self.faults += 1
            raise TransientError(
                f"injected: LLM API returned HTTP 503 (call {self.calls})")
        if u < self.error_rate + self.timeout_rate:
            self.faults += 1
            raise TimeoutError(
                f"injected: LLM API stalled past the deadline "
                f"(call {self.calls})")
        if u < self.error_rate + self.timeout_rate + self.malformed_rate:
            self.faults += 1
            return _MALFORMED_REPLY
        return self.inner.complete(prompt)

    # resumable-campaign state (see KernelScientist.resume)
    def state_dict(self) -> dict:
        inner = getattr(self.inner, "state_dict", None)
        return {"calls": self.calls, "faults": self.faults,
                "inner": inner() if inner else None}

    def load_state_dict(self, d: dict) -> None:
        self.calls = d["calls"]
        self.faults = d.get("faults", 0)
        if d.get("inner") is not None:
            self.inner.load_state_dict(d["inner"])


class FlakyService:
    """Wrap an ``EvaluationService`` and inject transient submission failures.

    Models the shared evaluation queue dropping or timing out a submission
    (paper §3.4) before it reaches the platform: the wrapped service's
    submission counter does not advance on an injected fault, exactly like a
    request that never arrived.
    """

    def __init__(self, inner, seed: int = 0, error_rate: float = 0.1,
                 timeout_rate: float = 0.0):
        if error_rate + timeout_rate > 1.0:
            raise ValueError("fault rates must sum to <= 1")
        self.inner = inner
        self.seed = seed
        self.error_rate = error_rate
        self.timeout_rate = timeout_rate
        self.calls = 0
        self.faults = 0

    def submit(self, source: str):
        self.calls += 1
        u = _uniform01(self.seed, "svc", self.calls)
        if u < self.error_rate:
            self.faults += 1
            raise TransientError(
                f"injected: evaluation queue dropped the submission "
                f"(call {self.calls})")
        if u < self.error_rate + self.timeout_rate:
            self.faults += 1
            raise TimeoutError(
                f"injected: evaluation queue exceeded its deadline "
                f"(call {self.calls})")
        return self.inner.submit(source)

    def state_dict(self) -> dict:
        inner = getattr(self.inner, "state_dict", None)
        return {"calls": self.calls, "faults": self.faults,
                "inner": inner() if inner else None}

    def load_state_dict(self, d: dict) -> None:
        self.calls = d["calls"]
        self.faults = d.get("faults", 0)
        if d.get("inner") is not None:
            self.inner.load_state_dict(d["inner"])

    def clone(self) -> "FlakyService":
        """An independent worker for ``EvalPool.of``: same platform (the
        inner service clones with an identical timing seed) but a distinct
        fault stream, as two routes into a shared queue would fail
        independently.  Chained cloning (clone of a clone) steps the fault
        seed again, giving every pool worker its own stream."""
        return FlakyService(self.inner.clone(), seed=self.seed + 1,
                            error_rate=self.error_rate,
                            timeout_rate=self.timeout_rate)

    def service_spec(self) -> dict:
        """JSON spec so a subprocess worker rebuilds this wrapper stack
        (``eval_worker.build_service``) with identical seeds and rates."""
        from .transport import service_spec_of
        return {"kind": "flaky", "inner": service_spec_of(self.inner),
                "seed": self.seed, "error_rate": self.error_rate,
                "timeout_rate": self.timeout_rate}

    def __getattr__(self, name):
        # delegate everything else (submissions, bench_configs, ...) so the
        # wrapper is a drop-in EvaluationService
        return getattr(self.inner, name)


class CircuitOpenError(RuntimeError):
    """Raised (or reported) when a circuit breaker is open: the dependency
    is presumed down, so the caller should take its fallback path *now*
    instead of paying the full retry/backoff schedule.  Deliberately not a
    ``TransientError``: ``retry_call`` must not retry it."""


class CircuitBreaker:
    """Classic closed / open / half-open breaker, deterministic by design.

    Guards a dependency (the LLM API, the evaluation backend) that is
    retried per call by ``retry_call``: once ``failure_threshold``
    *consecutive* calls have failed even after their retries, the breaker
    opens and subsequent calls are refused up front — the scientist flips
    straight to its rule-based fallback instead of paying the full backoff
    schedule against a dead dependency on every stage.

    Recovery is probed after ``cooldown_calls`` *refused calls* rather than
    after a wall-clock interval: the campaign's behaviour stays a pure
    function of the call sequence (no clock reads), which preserves the
    kill-and-resume trajectory-identity contract.  The call that ends the
    cooldown is admitted as the half-open probe; its outcome closes the
    breaker (success) or re-opens it for another cooldown (failure).
    """

    def __init__(self, failure_threshold: int = 3, cooldown_calls: int = 8,
                 name: str = "breaker"):
        if failure_threshold < 1:
            raise ValueError("failure_threshold must be >= 1")
        if cooldown_calls < 1:
            raise ValueError("cooldown_calls must be >= 1")
        self.failure_threshold = failure_threshold
        self.cooldown_calls = cooldown_calls
        self.name = name
        self.state = "closed"
        self.failures = 0            # consecutive, while closed
        self.skips = 0               # refused calls, while open
        self.trips = 0               # lifetime closed->open transitions

    def allow(self) -> bool:
        """Admit this call?  Counts one cooldown tick when open; the call
        that completes the cooldown is admitted as the half-open probe."""
        if self.state == "closed":
            return True
        if self.state == "open":
            self.skips += 1
            if self.skips >= self.cooldown_calls:
                self.state = "half_open"
                return True          # this call IS the probe
            return False
        return False                 # half_open: one probe already in flight

    def record_success(self) -> None:
        self.state = "closed"
        self.failures = 0
        self.skips = 0

    def record_failure(self) -> None:
        if self.state == "half_open":
            self.state = "open"      # probe failed: restart the cooldown
            self.skips = 0
            return
        self.failures += 1
        if self.state == "closed" and self.failures >= self.failure_threshold:
            self.state = "open"
            self.skips = 0
            self.trips += 1

    def state_dict(self) -> dict:
        return {"state": self.state, "failures": self.failures,
                "skips": self.skips, "trips": self.trips}

    def load_state_dict(self, d: dict) -> None:
        self.state = d.get("state", "closed")
        self.failures = d.get("failures", 0)
        self.skips = d.get("skips", 0)
        self.trips = d.get("trips", 0)


class CrashService:
    """Wrap an ``EvaluationService`` and deterministically *kill the whole
    worker process* mid-benchmark — the fault class that distinguishes a
    distributed campaign from a threaded one: a segfaulting kernel, an OOM
    kill, a preempted host.

    ``os._exit`` (no cleanup, no Python unwinding) models a hard death; the
    draw is keyed on ``(seed, incarnation, call_index)``, so a respawned
    worker (stepped incarnation — ``SubprocessTransport`` passes it through
    ``eval_worker.build_service``) faces a fresh fault stream and the
    resubmitted job eventually passes rather than crash-looping at the same
    call forever.  Only meaningful inside a subprocess worker: in-process it
    would take the campaign (or the test runner) down with it, which is
    exactly the failure mode the subprocess transport exists to contain.
    """

    def __init__(self, inner, seed: int = 0, crash_rate: float = 0.1,
                 incarnation: int = 0):
        if not 0.0 <= crash_rate <= 1.0:
            raise ValueError("crash_rate must be in [0, 1]")
        self.inner = inner
        self.seed = seed
        self.crash_rate = crash_rate
        self.incarnation = incarnation
        self.calls = 0

    def submit(self, source: str):
        self.calls += 1
        u = _uniform01(self.seed, "kill", self.incarnation, self.calls)
        if u < self.crash_rate:
            os._exit(17)          # hard worker death, mid-benchmark
        return self.inner.submit(source)

    def clone(self) -> "CrashService":
        return CrashService(self.inner.clone(), seed=self.seed + 1,
                            crash_rate=self.crash_rate,
                            incarnation=self.incarnation)

    def service_spec(self) -> dict:
        from .transport import service_spec_of
        return {"kind": "crash", "inner": service_spec_of(self.inner),
                "seed": self.seed, "crash_rate": self.crash_rate}

    def __getattr__(self, name):
        return getattr(self.inner, name)


class CorruptTimingService:
    """Wrap an ``EvaluationService`` and corrupt a fraction of ``ok``
    verdicts' timings — the silent measurement failure ``core.integrity``'s
    ``TimingAuditor`` exists to catch (a thermal-throttled device, a
    contended host, a platform bug reporting the wrong kernel's numbers).

    The corruption draw is keyed on ``(seed, source_hash)`` — content, not
    call order — so the *same* kernel source is corrupted (or not) on every
    worker, every incarnation, and every quorum-free resubmission, exactly
    like the platform's content-keyed jitter.  Crucially the auditor's
    *salted* quorum samples hash differently and therefore draw their own
    (mostly clean) corruption verdicts, which is what lets median-of-k
    recover the true timing.  ``clone()`` keeps the same seed for the same
    reason: corruption must be a property of the submission, not of which
    worker served it, or ``workers=N`` would diverge from ``workers=1``.
    """

    def __init__(self, inner, seed: int = 0, corrupt_rate: float = 0.1,
                 factor: float = 5.0):
        if not 0.0 <= corrupt_rate <= 1.0:
            raise ValueError("corrupt_rate must be in [0, 1]")
        if factor <= 1.0:
            raise ValueError("factor must be > 1")
        self.inner = inner
        self.seed = seed
        self.corrupt_rate = corrupt_rate
        self.factor = factor
        self.corruptions = 0

    def submit(self, source: str):
        res = self.inner.submit(source)
        if res.status != "ok" or not res.timings_us:
            return res
        skey = hashlib.sha256(source.encode()).hexdigest()
        if _uniform01(self.seed, "corrupt", skey) >= self.corrupt_rate:
            return res
        self.corruptions += 1
        scale = (self.factor
                 if _uniform01(self.seed, "corrupt-dir", skey) < 0.5
                 else 1.0 / self.factor)
        timings = {k: v * scale for k, v in res.timings_us.items()}
        return type(res)(res.status, res.error, timings)

    def clone(self) -> "CorruptTimingService":
        # SAME seed on purpose: corruption is content-keyed, so every
        # worker must agree on which sources are corrupted (see class doc).
        return CorruptTimingService(self.inner.clone(), seed=self.seed,
                                    corrupt_rate=self.corrupt_rate,
                                    factor=self.factor)

    def service_spec(self) -> dict:
        from .transport import service_spec_of
        return {"kind": "corrupt_timing",
                "inner": service_spec_of(self.inner), "seed": self.seed,
                "corrupt_rate": self.corrupt_rate, "factor": self.factor}

    def __getattr__(self, name):
        return getattr(self.inner, name)


#: Source marker that makes ``PoisonService`` kill its worker.
POISON_MARKER = "POISON"


class PoisonService:
    """Wrap an ``EvaluationService`` and hard-kill the worker process when
    the submitted source contains :data:`POISON_MARKER` — a *deterministic*
    worker-killer, unlike ``CrashService``'s random one.  This models the
    poison-kernel class (infinite loop, device wedge, segfault) that dies
    *every* time it runs: without ``core.integrity.Quarantine`` the
    evolutionary loop burns ``max_requeues`` worker deaths on every
    rediscovery of the same genome.  Subprocess workers only — in-process
    it would take the test runner down, which is the point of the marker
    check living behind the transport boundary."""

    def __init__(self, inner, marker: str = POISON_MARKER):
        self.inner = inner
        self.marker = marker

    def submit(self, source: str):
        if self.marker in source:
            os._exit(23)          # hard worker death: the kernel wedged it
        return self.inner.submit(source)

    def clone(self) -> "PoisonService":
        return PoisonService(self.inner.clone(), marker=self.marker)

    def service_spec(self) -> dict:
        from .transport import service_spec_of
        return {"kind": "poison", "inner": service_spec_of(self.inner),
                "marker": self.marker}

    def __getattr__(self, name):
        return getattr(self.inner, name)


class DriftService:
    """Wrap an ``EvaluationService`` and let incarnation 0 *drift*: after
    ``drift_after`` submissions, every ``ok`` verdict's timings are scaled
    by ``drift_factor`` — the slow measurement skew of an overheating or
    contended device, invisible to per-verdict checks because it biases
    *every* verdict consistently.  ``core.integrity``'s canary sentinel is
    the detector: its known-timing kernel shifts with the drift.  A
    respawned worker (stepped incarnation) measures clean again, modelling
    a device reset; ``respawn()`` lets the in-process transport step the
    incarnation without a process boundary."""

    def __init__(self, inner, drift_after: int = 0, drift_factor: float = 1.5,
                 incarnation: int = 0):
        if drift_factor <= 0:
            raise ValueError("drift_factor must be positive")
        self.inner = inner
        self.drift_after = drift_after
        self.drift_factor = drift_factor
        self.incarnation = incarnation
        self.calls = 0

    def _drifting(self) -> bool:
        return (self.incarnation == 0 and self.drift_after > 0
                and self.calls > self.drift_after)

    def submit(self, source: str):
        self.calls += 1
        res = self.inner.submit(source)
        if self._drifting() and res.status == "ok" and res.timings_us:
            timings = {k: v * self.drift_factor
                       for k, v in res.timings_us.items()}
            return type(res)(res.status, res.error, timings)
        return res

    def respawn(self) -> None:
        """Device reset: the replacement worker measures clean."""
        self.incarnation += 1
        self.calls = 0

    def clone(self) -> "DriftService":
        return DriftService(self.inner.clone(), drift_after=self.drift_after,
                            drift_factor=self.drift_factor,
                            incarnation=self.incarnation)

    def service_spec(self) -> dict:
        from .transport import service_spec_of
        return {"kind": "drift", "inner": service_spec_of(self.inner),
                "drift_after": self.drift_after,
                "drift_factor": self.drift_factor}

    def state_dict(self) -> dict:
        inner = getattr(self.inner, "state_dict", None)
        return {"calls": self.calls, "incarnation": self.incarnation,
                "inner": inner() if inner else None}

    def load_state_dict(self, d: dict) -> None:
        self.calls = d["calls"]
        self.incarnation = d.get("incarnation", 0)
        if d.get("inner") is not None:
            self.inner.load_state_dict(d["inner"])

    def __getattr__(self, name):
        return getattr(self.inner, name)
