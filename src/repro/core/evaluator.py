"""EvaluationService — the black-box testing/benchmark platform (paper §3.4).

Reproduces the competition interface constraints exactly:
  * submissions are **source text**, compiled server-side; compile/lowering
    failures come back as feedback strings;
  * numerical correctness is verified against a reference oracle before any
    timing is reported;
  * the only performance signal is end-to-end time per benchmark MxKxN
    configuration — no profiler;
  * submissions are processed **sequentially** ("good citizen", §3.4) — the
    service raises a typed ``ServiceBusyError`` on concurrent use.  Scaling
    comes from running *several* services behind ``core.evalpool.EvalPool``,
    never from violating the per-service contract.

Two timing backends:
  * ``cost_model`` — analytic TPU-v5e timing from the submission's GENOME
    metadata (this container has no TPU; the model is the platform).  Its
    terms are the §Roofline terms: max(MXU, HBM, VPU) + pipeline overheads.
  * ``wall_clock`` — really executes the submitted kernel (interpret mode on
    CPU) and times it; used by tests and examples with small configurations,
    where it is a true black box.
"""
from __future__ import annotations

import dataclasses
import hashlib
import math
import threading
import time
from typing import Optional

import numpy as np

from . import codegen
from .resilience import ServiceBusyError
from .genome import (
    HBM_BW, MXU_BF16_FLOPS, MXU_F32_FLOPS, SCALE_BLOCK, VMEM_USABLE,
    VPU_F32_FLOPS, KernelGenome,
)
from .population import BENCH_CONFIGS_18, config_key

LAUNCH_OVERHEAD_US = 15.0


class PlatformCompileError(RuntimeError):
    pass


# ---------------------------------------------------------------------------
# Analytic TPU-v5e timing model (the platform's ground truth in this repo)
# ---------------------------------------------------------------------------
def _ceil(x: int, m: int) -> int:
    return -(-x // m) * m


def estimate_us(genome: KernelGenome, m: int, n: int, k: int) -> float:
    """Estimated execution time in microseconds on one TPU v5e chip."""
    if genome.style == "library":
        # separate f32 dequant pass (read fp8 + write bf16, both operands),
        # then a well-blocked XLA matmul at ~75% MXU utilisation
        deq = 3 * (m * k + k * n) / HBM_BW
        mm_bytes = 2 * (m * k + k * n) + 2 * m * n
        mm = max(2 * m * n * k / (MXU_BF16_FLOPS * 0.75), mm_bytes / HBM_BW)
        return (deq + mm) * 1e6 + LAUNCH_OVERHEAD_US

    if genome.style == "naive":
        vmem = (m * k + k * n) + 4 * m * n + 2 * m * n
        if vmem > VMEM_USABLE:
            raise PlatformCompileError(
                f"RESOURCE_EXHAUSTED: single-program kernel requires "
                f"{vmem/2**20:.0f} MiB VMEM ({VMEM_USABLE/2**20:.0f} MiB "
                f"available): program allocation failed")
        t = max(2 * m * n * k / MXU_F32_FLOPS,
                (m * k + k * n + 2 * m * n) / HBM_BW)
        return t * 1e6 + LAUNCH_OVERHEAD_US

    # ---- blocked kernel: mirror run()'s clamping/padding exactly ----------
    bm = min(genome.block_m, _ceil(m, 128))
    bn = min(genome.block_n, _ceil(n, 128))
    bk = min(genome.block_k, _ceil(k, 128))
    mp, np_, kp = _ceil(m, bm), _ceil(n, bn), _ceil(k, bk)
    gm, gn, gk_total = mp // bm, np_ // bn, kp // bk
    ks = min(genome.k_split, gk_total)
    while gk_total % ks:
        ks -= 1

    # HBM traffic: A re-streamed once per N-block, B once per M-block
    # (index-map invariance gives no further reuse with K innermost).
    a_bytes = mp * kp * gn
    b_bytes = kp * np_ * gm
    scale_bytes = (mp * (kp // SCALE_BLOCK) * 4 * gn
                   + (kp // SCALE_BLOCK) * (np_ // SCALE_BLOCK) * 4 * gm)
    if ks > 1:  # f32 partials: write ks copies, read back, write bf16 final
        out_bytes = 4 * mp * np_ * ks * 2 + 2 * mp * np_
    else:
        out_bytes = 2 * mp * np_
    hbm = (a_bytes + b_bytes + scale_bytes + out_bytes) / HBM_BW

    rate = (MXU_BF16_FLOPS if genome.compute_dtype == "bfloat16"
            else MXU_F32_FLOPS)
    # accumulator revisit cost shrinks as the K tile grows
    util = 1.0 - 0.15 * (SCALE_BLOCK / bk)
    compute = 2 * mp * np_ * kp / (rate * util)

    n_sub_total = kp // SCALE_BLOCK
    if genome.scale_application == "scale_acc":
        vpu_flops = 3.0 * mp * np_ * n_sub_total
    else:  # dequantize both tiles on every use
        vpu_flops = 2.0 * (mp * kp * gn + kp * np_ * gm)
    if ks > 1:
        vpu_flops += ks * mp * np_  # final partial-sum reduction
    vpu = vpu_flops / VPU_F32_FLOPS

    # pipeline prologue/epilogue: first input fetch + last output drain
    overhead = 2 * (bm * bk + bk * bn) / HBM_BW
    return max(compute, hbm, vpu) * 1e6 + overhead * 1e6 + LAUNCH_OVERHEAD_US


# ---------------------------------------------------------------------------
@dataclasses.dataclass
class EvalResult:
    # ok | compile_error | runtime_error | incorrect — platform verdicts —
    # plus pool-level outcomes worker_error (requeue budget exhausted) and
    # quarantined (content hash blacklisted by core.integrity.Quarantine)
    status: str
    error: str = ""
    timings_us: dict = dataclasses.field(default_factory=dict)


class EvaluationService:
    def __init__(self, backend: str = "cost_model",
                 bench_configs=BENCH_CONFIGS_18,
                 correctness_config=(256, 256, 256),
                 noise: float = 0.0, seed: int = 0,
                 rtol: float = 0.06, latency_s: float = 0.0) -> None:
        if backend not in ("cost_model", "wall_clock"):
            raise ValueError(f"unknown backend {backend!r}")
        self.backend = backend
        self.bench_configs = tuple(bench_configs)
        self.correctness_config = correctness_config
        self.noise = noise
        self.seed = seed
        self.rtol = rtol
        self.latency_s = latency_s   # models the shared queue's service delay
        self.submissions = 0
        self._lock = threading.Lock()
        # per-(config, seed) memo of problem tensors and the reference-oracle
        # output: the correctness config never changes within a campaign, so
        # the quantization + reference matmul are computed once, not per
        # submission
        self._memo: dict = {}

    # ------------------------------------------------------------------ api
    def submit(self, source: str) -> EvalResult:
        """Sequential black-box evaluation of one kernel source."""
        if not self._lock.acquire(blocking=False):
            raise ServiceBusyError(
                "EvaluationService is sequential-only (paper §3.4): a "
                "submission is already in flight")
        try:
            self.submissions += 1
            if self.latency_s:
                time.sleep(self.latency_s)
            return self._evaluate(source)
        finally:
            self._lock.release()

    def clone(self) -> "EvaluationService":
        """An identically-configured independent worker (for ``EvalPool``).

        The clone shares the timing seed: benchmark jitter is keyed on
        ``(seed, sha256(source), config)``, so any worker evaluating a given
        source reports the same timings — which worker a submission lands on
        never affects the campaign trajectory."""
        return EvaluationService(
            backend=self.backend, bench_configs=self.bench_configs,
            correctness_config=self.correctness_config, noise=self.noise,
            seed=self.seed, rtol=self.rtol, latency_s=self.latency_s)

    def service_spec(self) -> dict:
        """JSON-serializable constructor spec, so a subprocess worker
        (``core.eval_worker``) rebuilds an identically-seeded service.  The
        timing seed travels with the spec, so a respawned worker reports
        exactly the timings its predecessor would have (content-keyed
        jitter makes the verdict a pure function of the spec + source)."""
        return {"kind": "evaluation", "backend": self.backend,
                "bench_configs": [list(c) for c in self.bench_configs],
                "correctness_config": list(self.correctness_config),
                "noise": self.noise, "seed": self.seed, "rtol": self.rtol,
                "latency_s": self.latency_s}

    # ------------------------------------------------- resumable campaigns
    def state_dict(self) -> dict:
        """Counters to persist across a campaign restart.  Since benchmark
        jitter became content-keyed, nothing here affects decisions — the
        counter is restored for accurate submissions/hour accounting only."""
        return {"submissions": self.submissions}

    def load_state_dict(self, d: dict) -> None:
        self.submissions = d["submissions"]

    # ------------------------------------------------------------ internals
    def _evaluate(self, source: str) -> EvalResult:
        # content address of the submission: benchmark jitter keys on it (not
        # on the submission counter), so identical sources always time
        # identically regardless of submission order or worker assignment —
        # the invariant that makes concurrent pools and result caches safe
        skey = hashlib.sha256(source.encode()).hexdigest()
        try:
            run, genome_json = codegen.load_kernel(source)
        except Exception as e:  # platform 'compile' feedback
            return EvalResult("compile_error", f"{type(e).__name__}: {e}")

        ok, err = self._check_correctness(run)
        if err is not None:
            # the kernel compiled/loaded but blew up while executing — a
            # distinct platform verdict so the selector/designer see accurate
            # feedback (a tiling bug, not a syntax error)
            return EvalResult("runtime_error", err)
        if not ok:
            return EvalResult("incorrect",
                              "output mismatch vs reference oracle "
                              f"(rtol {self.rtol}) on "
                              f"{self.correctness_config}")

        if self.backend == "cost_model":
            if not genome_json:
                return EvalResult(
                    "compile_error",
                    "platform rejected submission: missing GENOME metadata "
                    "(required for scheduling on the timing fleet)")
            try:
                genome = KernelGenome.from_json(genome_json)
                timings = {}
                for cfg in self.bench_configs:
                    t = estimate_us(genome, *cfg)
                    timings[config_key(cfg)] = self._jitter(t, cfg, skey)
            except PlatformCompileError as e:
                return EvalResult("compile_error", str(e))
            return EvalResult("ok", timings_us=timings)

        timings = {}
        for cfg in self.bench_configs:
            try:
                timings[config_key(cfg)] = self._time_wall(run, cfg)
            except Exception as e:
                return EvalResult("runtime_error",
                                  f"{type(e).__name__} on {cfg}: {e}")
        return EvalResult("ok", timings_us=timings)

    def _problem(self, cfg, seed=0):
        """Quantized problem tensors for one config, memoized per
        ``(config, seed)`` — regenerating + requantizing them for every
        submission was pure waste (the config set is fixed per campaign)."""
        memo_key = ("problem", tuple(cfg), seed)
        if memo_key in self._memo:
            return self._memo[memo_key]
        from repro.kernels import ref
        import jax.numpy as jnp
        m, n, k = cfg
        rng = np.random.default_rng(seed)
        a32 = jnp.asarray(rng.standard_normal((m, k)), jnp.float32)
        b32 = jnp.asarray(rng.standard_normal((k, n)), jnp.float32)
        aq, a_s = ref.quantize_blockwise(a32, jnp.float8_e4m3fn)
        bq, b_s = ref.quantize_blockwise_2d(b32, jnp.float8_e4m3fn)
        self._memo[memo_key] = (aq, bq, a_s, b_s)
        return self._memo[memo_key]

    def _oracle(self, cfg, seed) -> np.ndarray:
        """Reference-oracle output, memoized per ``(config, seed)``: the
        quantization + reference matmul run once per service, not once per
        submission."""
        memo_key = ("oracle", tuple(cfg), seed)
        if memo_key in self._memo:
            return self._memo[memo_key]
        from repro.kernels import ref
        aq, bq, a_s, b_s = self._problem(cfg, seed=seed)
        want = np.asarray(ref.scaled_gemm(aq, bq, a_s, b_s), dtype=np.float32)
        self._memo[memo_key] = want
        return want

    def _check_correctness(self, run) -> tuple:
        """Returns (is_correct, compile_error_or_None)."""
        m, n, k = self.correctness_config
        aq, bq, a_s, b_s = self._problem((m, n, k), seed=1234)
        want = self._oracle((m, n, k), seed=1234)
        try:
            got = np.asarray(run(aq, bq, a_s, b_s), dtype=np.float32)
        except Exception as e:
            return False, f"{type(e).__name__} during execution: {e}"
        if got.shape != want.shape:
            return False, None
        scale = float(np.max(np.abs(np.asarray(want)))) or 1.0
        return bool(np.max(np.abs(got - np.asarray(want))) <= self.rtol * scale), None

    def _time_wall(self, run, cfg) -> float:
        import jax
        args = self._problem(cfg)
        out = run(*args)
        jax.block_until_ready(out)
        best = float("inf")
        for _ in range(3):
            t0 = time.perf_counter()
            jax.block_until_ready(run(*args))
            best = min(best, time.perf_counter() - t0)
        return best * 1e6

    def _jitter(self, t_us: float, cfg, source_key: str) -> float:
        """Deterministic benchmark noise, keyed on the submission's content
        address (``sha256(source)``) rather than the global submission
        counter: a concurrent pool has no stable submission ordering, so the
        counter would make timings depend on scheduling.  Content keying
        makes the reported timings a pure function of (platform seed,
        source, config) — identical across workers, resubmissions, and
        resumed campaigns."""
        if not self.noise:
            return t_us
        h = hashlib.sha256(
            f"{self.seed}:{source_key}:{cfg}".encode()).digest()
        u = int.from_bytes(h[:8], "big") / 2**64
        v = int.from_bytes(h[8:16], "big") / 2**64
        gauss = math.sqrt(-2 * math.log(max(u, 1e-12))) * math.cos(2 * math.pi * v)
        return t_us * math.exp(self.noise * gauss)
