"""Structured JSONL event log for the Kernel Scientist campaign.

Every observable of the discovery process — stage start/end with durations,
retries and fallbacks, per-submission evaluation outcomes, generation
summaries — is appended as one JSON object per line to ``events.jsonl`` in
the campaign workdir (and kept in memory when no workdir is set).  The log is
append-only so a resumed campaign extends the same file, and it is consumed
by ``benchmarks/trajectory.py`` for the §4.4 discovery-process figure
(best-so-far curve annotated with retry/fallback density and stage
latencies).

Events are *observational*: nothing in the loop reads them back, so wall
timestamps here never affect resume determinism.

Worker lifecycle events (``WORKER_LIFECYCLE_EVENTS``) chronicle the
distributed evaluation layer: spawns/exits/deaths of transport workers,
requeues of in-flight jobs after a death, and pool pause/resume — the
observables a campaign operator greps first when a multi-day run slows
down.  ``worker_lifecycle()`` filters them per worker index.
"""
from __future__ import annotations

import json
import pathlib
import threading
import time
from typing import Optional

#: Events emitted by the evalpool/transport layer about worker health.
WORKER_LIFECYCLE_EVENTS = ("worker_spawn", "worker_exit", "worker_died",
                           "worker_requeue", "worker_respawn",
                           "pool_pause", "pool_resume")

#: Events emitted by the verdict-trust layer (``core.integrity``): audit
#: flags and quorum resolutions, quarantine adds/blocks, canary checks and
#: drift responses, circuit-breaker transitions, health snapshots, and
#: budget stops.  The substream an operator greps to answer "can I trust
#: this campaign's timings?".
INTEGRITY_EVENTS = ("audit_flag", "audit_quorum", "quarantine_add",
                    "quarantine_block", "canary", "worker_drift",
                    "worker_respawn", "verdict_invalidated", "breaker",
                    "health", "budget_stop", "busy_reroute")


class EventLog:
    def __init__(self, path=None, clock=time.time) -> None:
        self.path = pathlib.Path(path) if path else None
        self.records: list[dict] = []
        self._seq = 0
        self._clock = clock
        # EvalPool workers emit from their own threads; keep seq + append
        # atomic so the JSONL stream stays well-ordered
        self._lock = threading.Lock()
        if self.path:
            self.path.parent.mkdir(parents=True, exist_ok=True)
            if self.path.exists():  # resumed campaign: continue the sequence
                try:
                    prior = self.read(self.path)
                    self._seq = prior[-1]["seq"] if prior else 0
                except (json.JSONDecodeError, KeyError):
                    self._seq = 0

    def emit(self, event: str, **fields) -> dict:
        with self._lock:
            self._seq += 1
            rec = {"seq": self._seq, "ts": round(self._clock(), 3),
                   "event": event, **fields}
            self.records.append(rec)
            if self.path:
                with open(self.path, "a") as f:
                    f.write(json.dumps(rec) + "\n")
        return rec

    # ------------------------------------------------------------- queries
    def counts(self, event: Optional[str] = None) -> dict:
        """event name -> count (or {} filtered to one event)."""
        out: dict[str, int] = {}
        for r in self.records:
            if event is None or r["event"] == event:
                out[r["event"]] = out.get(r["event"], 0) + 1
        return out

    def select(self, event: str, **where) -> list[dict]:
        return [r for r in self.records if r["event"] == event
                and all(r.get(k) == v for k, v in where.items())]

    def worker_lifecycle(self, worker: Optional[int] = None) -> list[dict]:
        """The worker-health substream (spawns, deaths, requeues,
        pause/resume), optionally filtered to one worker index."""
        out = [r for r in self.records
               if r["event"] in WORKER_LIFECYCLE_EVENTS]
        if worker is not None:
            out = [r for r in out if r.get("worker") == worker]
        return out

    def integrity_events(self, event: Optional[str] = None) -> list[dict]:
        """The verdict-trust substream (audits, quarantines, canaries,
        breakers, health), optionally filtered to one event name."""
        wanted = INTEGRITY_EVENTS if event is None else (event,)
        return [r for r in self.records if r["event"] in wanted]

    def stage_durations(self) -> dict:
        """stage name -> list of duration_s from stage_end events."""
        out: dict[str, list] = {}
        for r in self.select("stage_end"):
            out.setdefault(r["stage"], []).append(r["duration_s"])
        return out

    @staticmethod
    def read(path) -> list[dict]:
        out = []
        for line in pathlib.Path(path).read_text().splitlines():
            line = line.strip()
            if line:
                out.append(json.loads(line))
        return out
