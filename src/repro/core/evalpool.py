"""Concurrent evaluation pool + content-addressed eval cache (paper §3.4).

The paper's campaigns were wall-clock-bound by the external evaluation
queue: one submission in flight at a time, variable service delays, and no
memory of what the platform had already timed.  This module removes both
bottlenecks without touching the per-service contract:

* ``EvalPool`` owns N *independent* ``EvaluationService`` workers behind a
  priority queue.  Each service still processes submissions strictly
  sequentially (it raises ``ServiceBusyError`` on concurrent use — the
  "good citizen" rule of §3.4); the pool is what scales, by routing queued
  submissions to whichever worker is free.  Campaign submissions outrank
  idle-time work: ``probe()`` enqueues autotune/benchmark probes at low
  priority, so they only consume a worker when no generation is waiting.

* ``EvalCache`` sits in front of the pool: a content-addressed result store
  keyed by ``sha256(source)``.  Duplicate submissions — identical fallback
  kernels, resubmissions after a resume, repeated genomes across
  generations — return the persisted ``EvalResult`` without consuming a
  platform slot.  Hits and misses stream to ``events.jsonl``.

Determinism contract (load-bearing — resume and N-worker equivalence both
depend on it):

1. **Cache key = jitter key = sha256(source).**  The evaluation platform's
   benchmark jitter is keyed on the submission's content address, *not* on
   a global submission counter: a concurrent pool has no stable submission
   ordering, so any order-dependent randomness would make the campaign
   trajectory depend on thread scheduling.  Content keying makes an
   ``EvalResult`` a pure function of (platform seed, source, config) —
   which is exactly the property that makes the result cacheable and makes
   a ``workers=N`` campaign population-identical to the ``workers=1`` run.
2. **Pool workers clone the service seed.**  ``EvalPool.of`` builds extra
   workers with ``service.clone()``; for ``EvaluationService`` the clone
   keeps the same timing seed, so worker assignment never changes timings.
   (Fault-injection wrappers clone with a stepped fault seed instead —
   faults are per-route, results are per-platform.)
3. **Results are applied in submission order.**  The pool completes jobs in
   any order; callers that need a deterministic trajectory (the scientist's
   generation drain) apply results sorted by record id, and persist
   pending/completed state after every application so a killed campaign
   resumes mid-drain, trajectory-identically.

The cache persists as append-only JSONL (``eval_cache.jsonl`` in the
campaign workdir): each completed evaluation appends one line at completion
time, independent of the scientist's state persistence, so a result that
was computed but whose campaign state never landed still saves a platform
slot after resume.  Only platform *verdicts* are cached (ok /
compile_error / runtime_error / incorrect); submissions that failed at the
queue level ("failed") never produced a verdict and are always retried.
"""
from __future__ import annotations

import hashlib
import itertools
import json
import pathlib
import queue
import threading
import time
from typing import Optional

from . import resilience
from .evaluator import EvalResult

#: Queue priorities (lower value = served first).
PRIORITY_CAMPAIGN = 0
PRIORITY_PROBE = 10
_PRIORITY_SHUTDOWN = 10 ** 9     # sentinels drain after all real work


class EvalCache:
    """Content-addressed ``EvalResult`` store keyed by ``sha256(source)``.

    In-memory by default; given a path, every ``put`` appends one JSONL line
    so a resumed campaign reloads all previously-computed verdicts.  Torn
    tail lines (crash mid-append) are skipped on load."""

    def __init__(self, path=None) -> None:
        self.path = pathlib.Path(path) if path else None
        self.hits = 0
        self.misses = 0
        self._entries: dict[str, EvalResult] = {}
        self._lock = threading.Lock()
        if self.path and self.path.exists():
            for line in self.path.read_text().splitlines():
                line = line.strip()
                if not line:
                    continue
                try:
                    d = json.loads(line)
                    self._entries[d["key"]] = EvalResult(
                        d["status"], d.get("error", ""),
                        d.get("timings_us", {}))
                except (json.JSONDecodeError, KeyError):
                    continue
        elif self.path:
            self.path.parent.mkdir(parents=True, exist_ok=True)

    @staticmethod
    def key_of(source: str) -> str:
        return hashlib.sha256(source.encode()).hexdigest()

    def __len__(self) -> int:
        return len(self._entries)

    def get(self, key: str) -> Optional[EvalResult]:
        """Lookup with hit/miss accounting (one call per submission)."""
        with self._lock:
            res = self._entries.get(key)
            if res is None:
                self.misses += 1
            else:
                self.hits += 1
            return res

    def put(self, key: str, result: EvalResult) -> None:
        with self._lock:
            if key in self._entries:
                return
            self._entries[key] = result
            if self.path:
                with open(self.path, "a") as f:
                    f.write(json.dumps(
                        {"key": key, "status": result.status,
                         "error": result.error,
                         "timings_us": result.timings_us}) + "\n")

    def stats(self) -> dict:
        return {"entries": len(self._entries), "hits": self.hits,
                "misses": self.misses}


class EvalHandle:
    """Future for one pooled submission.

    ``result()`` blocks until the evaluation completes and returns the
    ``EvalResult`` — or re-raises whatever the worker raised (including
    ``BaseException`` such as ``KeyboardInterrupt``, so a killed campaign
    still unwinds through the drain loop)."""

    def __init__(self, key: str, tag=None) -> None:
        self.key = key
        self.tag = tag            # caller metadata (record id) for events
        self.cached = False
        self.worker: Optional[int] = None
        self.duration_s = 0.0
        self._event = threading.Event()
        self._result: Optional[EvalResult] = None
        self._exc: Optional[BaseException] = None

    def done(self) -> bool:
        return self._event.is_set()

    def result(self, timeout: Optional[float] = None) -> EvalResult:
        if not self._event.wait(timeout):
            raise TimeoutError(f"evaluation of {self.key[:12]} still running")
        if self._exc is not None:
            raise self._exc
        return self._result

    def _finish(self, result=None, exc=None) -> None:
        self._result, self._exc = result, exc
        self._event.set()


class EvalPool:
    """N sequential-only evaluation services behind one priority queue.

    Worker threads are bound 1:1 to services, spawn on demand, and exit
    after a short idle period (no resource leak across many short-lived
    pools).  A submission whose service turns out busy (external
    contention) raises ``ServiceBusyError``, which the retry policy treats
    as immediately-reroutable — retried with zero backoff — rather than as
    a platform fault worth exponential delay."""

    def __init__(self, services, cache: Optional[EvalCache] = None,
                 retry_policy: Optional[resilience.RetryPolicy] = None,
                 events=None, sleep=time.sleep,
                 idle_timeout_s: float = 0.5) -> None:
        services = list(services)
        if not services:
            raise ValueError("EvalPool needs at least one service")
        self.services = services
        self.cache = cache
        self.retry_policy = retry_policy or resilience.DEFAULT_POLICY
        self.events = events
        self._sleep = sleep
        self._idle_s = idle_timeout_s
        self._queue: queue.PriorityQueue = queue.PriorityQueue()
        self._threads: dict[int, threading.Thread] = {}
        self._lock = threading.Lock()
        self._seq = itertools.count()
        self._closed = False

    # ----------------------------------------------------------- construct
    @classmethod
    def of(cls, service, workers: int = 1, **kwargs) -> "EvalPool":
        """Pool ``service`` plus ``workers - 1`` clones of it.

        Cloning is chained (each worker clones the previous one) so
        wrappers that step per-clone state — e.g. ``FlakyService`` fault
        seeds — give every worker an independent stream."""
        if workers < 1:
            raise ValueError("workers must be >= 1")
        svcs = [service]
        while len(svcs) < workers:
            clone = getattr(svcs[-1], "clone", None)
            if clone is None:
                raise TypeError(
                    f"{type(svcs[-1]).__name__} has no clone(); pass the "
                    f"worker services explicitly: EvalPool(services=[...])")
            svcs.append(clone())
        return cls(svcs, **kwargs)

    # ----------------------------------------------------------------- api
    def submit_async(self, source: str, priority: int = PRIORITY_CAMPAIGN,
                     tag=None) -> EvalHandle:
        """Enqueue one submission; returns immediately with its handle."""
        if self._closed:
            raise RuntimeError("EvalPool is closed")
        handle = EvalHandle(EvalCache.key_of(source), tag=tag)
        self._queue.put((priority, next(self._seq), source, handle))
        self._ensure_workers()
        return handle

    def submit(self, source: str, **kwargs) -> EvalResult:
        """Blocking convenience wrapper (drop-in for a bare service)."""
        return self.submit_async(source, **kwargs).result()

    def probe(self, source: str, tag=None) -> EvalHandle:
        """Low-priority idle-time work (autotune/benchmark probes): only
        reaches a worker when no campaign submission is queued."""
        return self.submit_async(source, priority=PRIORITY_PROBE, tag=tag)

    @property
    def submissions(self) -> int:
        """Total platform slots consumed across all workers."""
        return sum(getattr(s, "submissions", 0) for s in self.services)

    def stats(self) -> dict:
        d = {"workers": len(self.services), "submissions": self.submissions}
        if self.cache is not None:
            d.update({f"cache_{k}": v for k, v in self.cache.stats().items()})
        return d

    def close(self, wait: bool = True) -> None:
        """Stop accepting work; sentinels drain after already-queued jobs."""
        with self._lock:
            if self._closed:
                return
            self._closed = True
            threads = list(self._threads.values())
        for _ in threads:
            self._queue.put((_PRIORITY_SHUTDOWN, next(self._seq), None, None))
        if wait:
            for t in threads:
                t.join()

    def __enter__(self) -> "EvalPool":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # ------------------------------------------------- resumable campaigns
    def state_dict(self) -> dict:
        return {"workers": [
            (s.state_dict() if hasattr(s, "state_dict") else None)
            for s in self.services]}

    def load_state_dict(self, d) -> None:
        if not d:
            return
        # pre-pool state.json persisted one bare service's state dict
        worker_states = d["workers"] if "workers" in d else [d]
        for svc, sd in zip(self.services, worker_states):
            if sd is not None and hasattr(svc, "load_state_dict"):
                svc.load_state_dict(sd)

    # ------------------------------------------------------------ internals
    def _emit(self, event: str, **fields) -> None:
        if self.events is not None:
            self.events.emit(event, **fields)

    def _ensure_workers(self) -> None:
        with self._lock:
            if self._closed:
                return
            for idx in range(len(self.services)):
                t = self._threads.get(idx)
                if t is None or not t.is_alive():
                    t = threading.Thread(target=self._worker, args=(idx,),
                                         name=f"evalpool-{idx}", daemon=True)
                    self._threads[idx] = t
                    t.start()

    def _worker(self, idx: int) -> None:
        svc = self.services[idx]
        while True:
            try:
                _, _, source, handle = self._queue.get(timeout=self._idle_s)
            except queue.Empty:
                with self._lock:
                    # exit only while provably idle: a job enqueued before
                    # this check keeps the thread alive; one enqueued after
                    # finds the thread dead and _ensure_workers respawns it
                    if self._queue.empty():
                        if self._threads.get(idx) is threading.current_thread():
                            del self._threads[idx]
                        return
                continue
            if source is None:        # shutdown sentinel
                with self._lock:
                    if self._threads.get(idx) is threading.current_thread():
                        del self._threads[idx]
                return
            self._run_job(svc, idx, source, handle)

    def _run_job(self, svc, idx: int, source: str, handle: EvalHandle) -> None:
        t0 = time.perf_counter()
        handle.worker = idx
        try:
            if self.cache is not None:
                res = self.cache.get(handle.key)
                if res is not None:
                    handle.cached = True
                    self._emit("eval_cache", outcome="hit",
                               key=handle.key[:12], tag=handle.tag,
                               worker=idx)
                    handle.duration_s = time.perf_counter() - t0
                    handle._finish(result=res)
                    return
                self._emit("eval_cache", outcome="miss",
                           key=handle.key[:12], tag=handle.tag, worker=idx)

            def on_retry(attempt, exc, delay):
                self._emit("retry", stage="evaluate", tag=handle.tag,
                           worker=idx, attempt=attempt,
                           error=f"{type(exc).__name__}: {exc}",
                           delay_s=round(delay, 3))

            res = resilience.retry_call(
                lambda: svc.submit(source), policy=self.retry_policy,
                on_retry=on_retry, sleep=self._sleep)
            if self.cache is not None:
                self.cache.put(handle.key, res)
            handle.duration_s = time.perf_counter() - t0
            handle._finish(result=res)
        except BaseException as e:
            # Exceptions (retries exhausted) become the caller's "failed"
            # verdict; BaseExceptions (KeyboardInterrupt) surface at drain
            # so a killed campaign unwinds exactly like the sequential loop.
            handle.duration_s = time.perf_counter() - t0
            handle._finish(exc=e)
