"""Distributed evaluation subsystem: the ``EvalBackend`` API, the pooled
scheduler, worker transports, and the content-addressed eval cache (§3.4).

The paper's campaigns were wall-clock-bound by the external evaluation
queue: one submission in flight at a time, variable service delays, no
memory of what the platform had already timed — and, over multi-day runs,
workers that die mid-benchmark.  This module is the eval-throughput
authority that removes those bottlenecks behind one small API.

``EvalBackend`` protocol
------------------------
Everything the scientist needs from an evaluation backend, and nothing
more: ``submit_async`` / ``probe`` / ``stats`` / ``state_dict`` /
``load_state_dict`` / ``close``.  ``EvalPool`` is the reference
implementation; anything satisfying the protocol (a remote queue client, a
recorded-fixture backend) plugs into ``KernelScientist(backend=...)``
unchanged.

``EvalPool`` — N sequential-only workers behind one priority queue
------------------------------------------------------------------
Each worker still processes submissions strictly sequentially (the "good
citizen" rule of §3.4 — a busy service raises ``ServiceBusyError``); the
pool is what scales, by routing queued submissions to whichever worker is
free.  Three priority tiers: ``PRIORITY_URGENT`` (jump the queue — e.g. a
re-evaluation the drain is blocked on) < ``PRIORITY_CAMPAIGN`` (generation
submissions) < ``PRIORITY_PROBE`` (idle-time autotune/benchmark probes).
``pause()`` stops workers from starting *new* jobs (in-flight evaluations
finish; the queue keeps accepting); ``resume()`` continues.

Transport matrix (see ``core.transport``)
-----------------------------------------
=============  =====================  ======================================
transport      worker                 failure domain
=============  =====================  ======================================
``inprocess``  service object called  none: a crash in any evaluation kills
               from a pool thread     the campaign process
``subprocess`` ``eval_worker`` child  one worker: death/stall is detected
               process, JSONL wire    (heartbeat + deadlines), the in-flight
               protocol               job is requeued at its original
                                      priority, the worker respawns lazily
                                      with a stepped incarnation
=============  =====================  ======================================

``EvalCache`` — content-addressed verdict store
-----------------------------------------------
Keyed by ``sha256(source)``, in front of the pool: duplicate submissions —
identical fallback kernels, resubmissions after a resume, repeated genomes
— return the persisted ``EvalResult`` without consuming a platform slot.
With ``max_entries`` set it is a size-capped LRU: ``get`` refreshes
recency, overflow evicts the least recently used, and the append-only
``eval_cache.jsonl`` is compacted (atomic rewrite of live entries) once
dead lines outnumber the cap.  Hits/misses/evictions stream to
``events.jsonl``.

Cross-transport determinism contract (load-bearing)
---------------------------------------------------
1. **Cache key = jitter key = sha256(source).**  Benchmark jitter keys on
   the submission's content address, never on submission order: an
   ``EvalResult`` is a pure function of (platform seed, source, config).
   This single invariant is what makes verdicts cacheable, makes
   ``workers=N`` population-identical to ``workers=1``, and makes a
   subprocess campaign with worker kills population-identical to an
   uninterrupted in-process run — a requeued job re-evaluates to the same
   verdict wherever and whenever it lands.
2. **Workers clone the platform seed.**  ``EvalPool.of`` builds extra
   workers with ``service.clone()`` (same timing seed; fault-injection
   wrappers step their *fault* seed instead), and ``SubprocessTransport``
   rebuilds children from ``service_spec()`` with the same seeds, so
   worker assignment and respawns never change timings.
3. **Results are applied in record-id order.**  The pool completes jobs in
   any order; the scientist's drain applies them sorted by record id and
   persists pending/completed state after every application, so
   kill-and-resume stays trajectory-identical across transports.
4. **Integrity re-measurement rides the same invariants.**  A quorum
   re-measure sample (``core.integrity.TimingAuditor.salted``) is the same
   kernel plus a trailing comment: the genome — and therefore the platform
   timing model — is unchanged, but the content address differs, so each
   sample is an independent *deterministic* jitter draw that caches like
   any other submission (a campaign killed mid-quorum replays completed
   samples as cache hits).  Canary sentinels go the other way: one constant
   source, so its verdict is constant on a healthy worker — which is why
   ``run_direct`` must bypass both the queue (the canary targets a
   *specific* worker) and the cache (a cached verdict would mask drift).
   Canary measurements never enter the cache and never consume a campaign
   submission slot in the drain.

Only platform *verdicts* are cached (ok / compile_error / runtime_error /
incorrect); submissions that failed at the queue level ("failed"), gave up
after repeated worker deaths ("worker_error"), or were quarantine-blocked
("quarantined") never produced a platform verdict and are never cached —
lifting a quarantine or raising ``max_requeues`` re-evaluates them fresh.
"""
from __future__ import annotations

import collections
import hashlib
import itertools
import json
import pathlib
import queue
import threading
import time
from typing import Optional, Protocol, runtime_checkable

from . import resilience
from .evaluator import EvalResult
from .transport import WorkerDiedError, WorkerTransport, make_transport

#: Queue priorities (lower value = served first).
PRIORITY_URGENT = -10            # jump the queue: drain-blocking work
PRIORITY_CAMPAIGN = 0            # generation submissions
PRIORITY_PROBE = 10              # idle-time autotune/benchmark probes
_PRIORITY_SHUTDOWN = 10 ** 9     # sentinels drain after all real work


@runtime_checkable
class EvalBackend(Protocol):
    """What ``KernelScientist`` requires of an evaluation backend.

    ``EvalPool`` implements it; so can a remote evaluation-queue client or
    a test double.  The contract: ``submit_async`` returns an
    :class:`EvalHandle`-like future immediately; ``probe`` is the
    low-priority lane; ``state_dict``/``load_state_dict`` carry whatever
    must survive a campaign restart; ``close`` quiesces workers."""

    def submit_async(self, source: str, priority: int = PRIORITY_CAMPAIGN,
                     tag=None) -> "EvalHandle": ...
    def probe(self, source: str, tag=None) -> "EvalHandle": ...
    def stats(self) -> dict: ...
    def state_dict(self) -> dict: ...
    def load_state_dict(self, d) -> None: ...
    def close(self, wait: bool = True) -> None: ...


class EvalCache:
    """Content-addressed ``EvalResult`` store keyed by ``sha256(source)``.

    In-memory by default; given a path, every ``put`` appends one JSONL
    line so a resumed campaign reloads all previously-computed verdicts
    (torn tail lines from a crash mid-append are skipped; later lines win
    over earlier ones for the same key).

    With ``max_entries`` set the cache is a bounded LRU: ``get`` refreshes
    an entry's recency, inserting past the cap evicts the least recently
    used entry, and the JSONL file is compacted in place (atomic tmp +
    rename of the live entries, in recency order) whenever evicted/dead
    lines outnumber ``max_entries`` — so a month-long campaign's cache file
    stays O(max_entries), not O(submissions)."""

    def __init__(self, path=None, max_entries: Optional[int] = None) -> None:
        if max_entries is not None and max_entries < 1:
            raise ValueError("max_entries must be >= 1 (or None)")
        self.path = pathlib.Path(path) if path else None
        self.max_entries = max_entries
        self.hits = 0
        self.misses = 0
        self.evictions = 0
        self.compactions = 0
        self._lines = 0           # JSONL lines currently in the file
        self._entries: collections.OrderedDict[str, EvalResult] = \
            collections.OrderedDict()
        self._lock = threading.Lock()
        if self.path and self.path.exists():
            for line in self.path.read_text().splitlines():
                line = line.strip()
                if not line:
                    continue
                try:
                    d = json.loads(line)
                    if d.get("invalidated"):
                        # tombstone (drift invalidation): later lines win,
                        # so drop whatever an earlier line established
                        self._lines += 1
                        self._entries.pop(d["key"], None)
                        continue
                    res = EvalResult(d["status"], d.get("error", ""),
                                     d.get("timings_us", {}))
                except (json.JSONDecodeError, KeyError):
                    continue
                self._lines += 1
                self._entries[d["key"]] = res
                self._entries.move_to_end(d["key"])
            # reload trims to the cap by file order (append order ~ recency)
            if self.max_entries is not None:
                while len(self._entries) > self.max_entries:
                    self._entries.popitem(last=False)
        elif self.path:
            self.path.parent.mkdir(parents=True, exist_ok=True)

    @staticmethod
    def key_of(source: str) -> str:
        return hashlib.sha256(source.encode()).hexdigest()

    def __len__(self) -> int:
        return len(self._entries)

    def get(self, key: str) -> Optional[EvalResult]:
        """Lookup with hit/miss accounting (one call per submission); a hit
        refreshes the entry's LRU recency."""
        with self._lock:
            res = self._entries.get(key)
            if res is None:
                self.misses += 1
            else:
                self.hits += 1
                self._entries.move_to_end(key)
            return res

    def put(self, key: str, result: EvalResult) -> None:
        with self._lock:
            if key in self._entries:
                self._entries.move_to_end(key)
                return
            self._entries[key] = result
            if self.path:
                with open(self.path, "a") as f:
                    f.write(self._line(key, result))
                self._lines += 1
            if self.max_entries is not None:
                while len(self._entries) > self.max_entries:
                    self._entries.popitem(last=False)
                    self.evictions += 1
                if (self.path
                        and self._lines - len(self._entries)
                        > self.max_entries):
                    self._compact()

    @staticmethod
    def _line(key: str, result: EvalResult) -> str:
        return json.dumps({"key": key, "status": result.status,
                           "error": result.error,
                           "timings_us": result.timings_us}) + "\n"

    def _compact(self) -> None:
        """Rewrite the JSONL file to just the live entries (LRU order, so a
        reload reconstructs recency).  Atomic: tmp + rename.  Caller holds
        the lock."""
        tmp = self.path.with_suffix(".tmp")
        tmp.write_text("".join(self._line(k, r)
                               for k, r in self._entries.items()))
        tmp.replace(self.path)
        self._lines = len(self._entries)
        self.compactions += 1

    def invalidate(self, key: str) -> bool:
        """Drop ``key``'s verdict — it was measured by a worker later found
        to be drifting, so it can no longer be trusted.  Persisted as an
        appended tombstone line (later lines win on reload); compaction
        clears tombstones.  Returns whether the key was present."""
        with self._lock:
            present = self._entries.pop(key, None) is not None
            if self.path:
                with open(self.path, "a") as f:
                    f.write(json.dumps({"key": key, "invalidated": True})
                            + "\n")
                self._lines += 1
            return present

    def compact(self) -> None:
        """Force a compaction (e.g. at campaign end)."""
        with self._lock:
            if self.path:
                self._compact()

    def stats(self) -> dict:
        d = {"entries": len(self._entries), "hits": self.hits,
             "misses": self.misses}
        if self.max_entries is not None:
            d.update(max_entries=self.max_entries,
                     evictions=self.evictions,
                     compactions=self.compactions)
        return d


class EvalHandle:
    """Future for one pooled submission.

    ``result()`` blocks until the evaluation completes and returns the
    ``EvalResult`` — or re-raises whatever the worker raised (including
    ``BaseException`` such as ``KeyboardInterrupt``, so a killed campaign
    still unwinds through the drain loop).  ``requeues`` counts how many
    times the job was re-enqueued after a worker *death*; ``busy_reroutes``
    counts re-enqueues because every retry found the worker occupied —
    deliberately separate counters, because a saturated-but-healthy pool
    must never exhaust a job's death budget."""

    def __init__(self, key: str, tag=None) -> None:
        self.key = key
        self.tag = tag            # caller metadata (record id) for events
        self.cached = False
        self.worker: Optional[int] = None
        self.duration_s = 0.0
        self.requeues = 0
        self.busy_reroutes = 0
        self._event = threading.Event()
        self._result: Optional[EvalResult] = None
        self._exc: Optional[BaseException] = None

    def done(self) -> bool:
        return self._event.is_set()

    def result(self, timeout: Optional[float] = None) -> EvalResult:
        if not self._event.wait(timeout):
            raise TimeoutError(f"evaluation of {self.key[:12]} still running")
        if self._exc is not None:
            raise self._exc
        return self._result

    def _finish(self, result=None, exc=None) -> None:
        self._result, self._exc = result, exc
        self._event.set()


class EvalPool:
    """N sequential-only evaluation workers behind one priority queue —
    the reference :class:`EvalBackend`.

    Worker threads are bound 1:1 to transport worker indices, spawn on
    demand, and exit after a short idle period (no resource leak across
    many short-lived pools).  A submission whose service turns out busy
    raises ``ServiceBusyError``, retried with zero backoff; a submission
    whose *worker dies* (subprocess transport) is requeued at its original
    priority — up to ``max_requeues`` times — and the worker respawns."""

    def __init__(self, services=None, cache: Optional[EvalCache] = None,
                 retry_policy: Optional[resilience.RetryPolicy] = None,
                 events=None, sleep=time.sleep,
                 idle_timeout_s: float = 0.5,
                 transport="inprocess",
                 transport_options: Optional[dict] = None,
                 max_requeues: int = 32,
                 max_busy_reroutes: int = 1000,
                 quarantine=None) -> None:
        services = list(services) if services is not None else []
        if not services and not isinstance(transport, WorkerTransport):
            raise ValueError("EvalPool needs at least one service "
                             "(or a constructed transport)")
        self.services = services
        self.cache = cache
        self.retry_policy = retry_policy or resilience.DEFAULT_POLICY
        self.events = events
        self._sleep = sleep
        self._idle_s = idle_timeout_s
        self.max_requeues = max_requeues
        self.max_busy_reroutes = max_busy_reroutes
        #: Optional ``core.integrity.Quarantine``: worker deaths feed it,
        #: quarantined content hashes short-circuit at submit time.
        self.quarantine = quarantine
        self.transport = make_transport(transport, services,
                                        retry_policy=self.retry_policy,
                                        options=transport_options)
        self.transport.emitter = self._emit
        self._queue: queue.PriorityQueue = queue.PriorityQueue()
        self._threads: dict[int, threading.Thread] = {}
        # one lock per worker index: serializes run_direct (canaries /
        # respawns target a *specific* worker) against that worker's thread
        self._worker_locks: dict[int, threading.Lock] = {}
        self._lock = threading.Lock()
        self._seq = itertools.count()
        self._closed = False
        self._unpaused = threading.Event()
        self._unpaused.set()

    # ----------------------------------------------------------- construct
    @classmethod
    def of(cls, service, workers: int = 1, **kwargs) -> "EvalPool":
        """Pool ``service`` plus ``workers - 1`` clones of it.

        Cloning is chained (each worker clones the previous one) so
        wrappers that step per-clone state — e.g. ``FlakyService`` fault
        seeds — give every worker an independent stream."""
        if workers < 1:
            raise ValueError("workers must be >= 1")
        svcs = [service]
        while len(svcs) < workers:
            clone = getattr(svcs[-1], "clone", None)
            if clone is None:
                raise TypeError(
                    f"{type(svcs[-1]).__name__} has no clone(); pass the "
                    f"worker services explicitly: EvalPool(services=[...])")
            svcs.append(clone())
        return cls(svcs, **kwargs)

    # ----------------------------------------------------------------- api
    def submit_async(self, source: str, priority: int = PRIORITY_CAMPAIGN,
                     tag=None) -> EvalHandle:
        """Enqueue one submission; returns immediately with its handle.

        A quarantined content hash never reaches a worker: its handle
        resolves instantly to a ``quarantined`` verdict (uncached, so
        lifting the quarantine re-evaluates it fresh)."""
        if self._closed:
            raise RuntimeError("EvalPool is closed")
        handle = EvalHandle(EvalCache.key_of(source), tag=tag)
        if self.quarantine is not None:
            reason = self.quarantine.blocked(handle.key)
            if reason is not None:
                self._emit("quarantine_block", key=handle.key[:12],
                           tag=handle.tag, reason=reason)
                handle._finish(result=EvalResult(
                    "quarantined", f"quarantined kernel: {reason}"))
                return handle
        self._queue.put((priority, next(self._seq), source, handle))
        self._ensure_workers()
        return handle

    def submit(self, source: str, **kwargs) -> EvalResult:
        """Blocking convenience wrapper (drop-in for a bare service)."""
        return self.submit_async(source, **kwargs).result()

    def probe(self, source: str, tag=None) -> EvalHandle:
        """Low-priority idle-time work (autotune/benchmark probes): only
        reaches a worker when no campaign submission is queued."""
        return self.submit_async(source, priority=PRIORITY_PROBE, tag=tag)

    def urgent(self, source: str, tag=None) -> EvalHandle:
        """Queue-jumping tier for drain-blocking work (e.g. re-evaluating
        the one kernel the scientist cannot advance without)."""
        return self.submit_async(source, priority=PRIORITY_URGENT, tag=tag)

    def run_direct(self, idx: int, source: str) -> EvalResult:
        """Run ``source`` on worker ``idx`` *now*, synchronously — bypassing
        both the queue and the cache.  This is the canary lane: drift
        detection needs the measurement to come from one specific worker
        (the queue routes to whoever is free) and to be freshly measured (a
        cache hit would mask drift).  Serialized against the worker's own
        thread via its per-index lock; blocks while that worker finishes
        its in-flight job.  Raises whatever the transport raises
        (``WorkerDiedError`` included) — callers classify failures."""
        if not 0 <= idx < self.transport.num_workers:
            raise ValueError(f"no worker {idx}")
        with self._lock_for(idx):
            return resilience.retry_call(
                lambda: self.transport.run(idx, source),
                policy=self.retry_policy, sleep=self._sleep)

    def respawn_worker(self, idx: int) -> None:
        """Force worker ``idx`` to be rebuilt (stepped incarnation) — the
        drift response: a replacement worker measures clean.  Serialized
        against the worker's in-flight job."""
        with self._lock_for(idx):
            self.transport.respawn(idx)

    # -------------------------------------------------------- pause/resume
    def pause(self) -> None:
        """Stop workers from *starting* new jobs.  In-flight evaluations
        finish; the queue keeps accepting submissions; ``close()`` on a
        paused pool unpauses it so queued work drains."""
        if self._unpaused.is_set():
            self._unpaused.clear()
            self._emit("pool_pause", queued=self._queue.qsize())

    def resume(self) -> None:
        if not self._unpaused.is_set():
            self._unpaused.set()
            self._emit("pool_resume", queued=self._queue.qsize())
            self._ensure_workers()

    @property
    def paused(self) -> bool:
        return not self._unpaused.is_set()

    # ---------------------------------------------------------- accounting
    @property
    def submissions(self) -> int:
        """Total platform slots consumed across all workers."""
        return self.transport.submissions

    def stats(self) -> dict:
        d = {"workers": self.transport.num_workers,
             "submissions": self.submissions,
             "transport": self.transport.kind,
             "paused": self.paused}
        if self.cache is not None:
            d.update({f"cache_{k}": v for k, v in self.cache.stats().items()})
        return d

    def close(self, wait: bool = True) -> None:
        """Stop accepting work; sentinels drain after already-queued jobs.
        A paused pool is unpaused first so nothing queued is stranded."""
        with self._lock:
            if self._closed:
                return
            self._closed = True
            threads = list(self._threads.values())
        self._unpaused.set()
        for _ in threads:
            self._queue.put((_PRIORITY_SHUTDOWN, next(self._seq), None, None))
        if wait:
            for t in threads:
                t.join()
        self.transport.close()

    def __enter__(self) -> "EvalPool":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # ------------------------------------------------- resumable campaigns
    def state_dict(self) -> dict:
        return {"workers": self.transport.worker_states()}

    def load_state_dict(self, d) -> None:
        if not d:
            return
        # pre-pool state.json persisted one bare service's state dict
        worker_states = d["workers"] if "workers" in d else [d]
        self.transport.load_worker_states(worker_states)

    # ------------------------------------------------------------ internals
    def _emit(self, event: str, **fields) -> None:
        if self.events is not None:
            self.events.emit(event, **fields)

    def _lock_for(self, idx: int) -> threading.Lock:
        with self._lock:
            lock = self._worker_locks.get(idx)
            if lock is None:
                lock = self._worker_locks[idx] = threading.Lock()
            return lock

    def _ensure_workers(self) -> None:
        with self._lock:
            if self._closed:
                return
            for idx in range(self.transport.num_workers):
                t = self._threads.get(idx)
                if t is None or not t.is_alive():
                    t = threading.Thread(target=self._worker, args=(idx,),
                                         name=f"evalpool-{idx}", daemon=True)
                    self._threads[idx] = t
                    t.start()

    def _worker(self, idx: int) -> None:
        while True:
            if not self._unpaused.is_set():
                # paused: never pop (or idle-exit past) queued work
                self._unpaused.wait(self._idle_s)
                continue
            try:
                prio, _, source, handle = self._queue.get(
                    timeout=self._idle_s)
            except queue.Empty:
                with self._lock:
                    # exit only while provably idle: a job enqueued before
                    # this check keeps the thread alive; one enqueued after
                    # finds the thread dead and _ensure_workers respawns it
                    if self._queue.empty():
                        if self._threads.get(idx) is threading.current_thread():
                            del self._threads[idx]
                        return
                continue
            if source is None:        # shutdown sentinel
                with self._lock:
                    if self._threads.get(idx) is threading.current_thread():
                        del self._threads[idx]
                return
            with self._lock_for(idx):
                self._run_job(idx, source, handle, prio)

    def _run_job(self, idx: int, source: str, handle: EvalHandle,
                 priority: int = PRIORITY_CAMPAIGN) -> None:
        t0 = time.perf_counter()
        handle.worker = idx
        try:
            if self.cache is not None:
                res = self.cache.get(handle.key)
                if res is not None:
                    handle.cached = True
                    self._emit("eval_cache", outcome="hit",
                               key=handle.key[:12], tag=handle.tag,
                               worker=idx)
                    handle.duration_s = time.perf_counter() - t0
                    handle._finish(result=res)
                    return
                self._emit("eval_cache", outcome="miss",
                           key=handle.key[:12], tag=handle.tag, worker=idx)

            def on_retry(attempt, exc, delay):
                self._emit("retry", stage="evaluate", tag=handle.tag,
                           worker=idx, attempt=attempt,
                           error=f"{type(exc).__name__}: {exc}",
                           delay_s=round(delay, 3))

            res = resilience.retry_call(
                lambda: self.transport.run(idx, source),
                policy=self.retry_policy, on_retry=on_retry,
                sleep=self._sleep)
            if self.cache is not None:
                self.cache.put(handle.key, res)
            handle.duration_s = time.perf_counter() - t0
            handle._finish(result=res)
        except resilience.ServiceBusyError as e:
            # every zero-backoff retry found this worker occupied: reroute —
            # re-enqueue at the original priority so whichever worker frees
            # up first takes it.  Deliberately NOT handle.requeues: a
            # saturated-but-healthy pool must never exhaust a job's
            # worker-death budget.
            handle.busy_reroutes += 1
            self._emit("busy_reroute", worker=idx, tag=handle.tag,
                       busy_reroutes=handle.busy_reroutes)
            if handle.busy_reroutes > self.max_busy_reroutes:
                handle.duration_s = time.perf_counter() - t0
                handle._finish(exc=RuntimeError(
                    f"rerouted {handle.busy_reroutes} times without finding "
                    f"a free worker: {e}"))
            else:
                self._queue.put((priority, next(self._seq), source, handle))
        except WorkerDiedError as e:
            # the worker died or stalled with this job in flight: requeue at
            # the original priority — any (respawned) worker re-evaluates to
            # the identical verdict, so the campaign trajectory is unchanged
            handle.requeues += 1
            self._emit("worker_requeue", worker=idx, tag=handle.tag,
                       requeues=handle.requeues, reason=str(e))
            handle.duration_s = time.perf_counter() - t0
            if self.quarantine is not None:
                deaths = self.quarantine.record_death(handle.key, str(e))
                blocked = self.quarantine.blocked(handle.key)
                if blocked is not None:
                    # this kernel kills workers deterministically: blacklist
                    # its content hash so rediscoveries cost zero deaths
                    self._emit("quarantine_add", key=handle.key[:12],
                               tag=handle.tag, deaths=deaths, reason=blocked)
                    handle._finish(result=EvalResult(
                        "quarantined", f"quarantined kernel: {blocked}"))
                    return
            if handle.requeues > self.max_requeues:
                # terminal *verdict*, not an exception: the campaign records
                # it in the logbook (score inf) and moves on — one doomed
                # kernel must not abort the drain.  Never cached.
                handle._finish(result=EvalResult(
                    "worker_error",
                    f"gave up after {handle.requeues} worker deaths: {e}"))
            else:
                self._queue.put((priority, next(self._seq), source, handle))
        except BaseException as e:
            # Exceptions (retries exhausted) become the caller's "failed"
            # verdict; BaseExceptions (KeyboardInterrupt) surface at drain
            # so a killed campaign unwinds exactly like the sequential loop.
            handle.duration_s = time.perf_counter() - t0
            handle._finish(exc=e)
