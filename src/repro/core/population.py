"""Kernel population: records, lineage, per-config benchmark timings.

Mirrors the paper's population mechanics exactly: each member has an ID, its
parents' IDs, and benchmark results over the competition's MxKxN
configurations; the Evolutionary Selector sees a compact table of exactly
this information (paper §3.1).
"""
from __future__ import annotations

import dataclasses
import json
import math
import pathlib
from typing import Optional

from .genome import KernelGenome

# ---------------------------------------------------------------------------
# Benchmark configurations.  The AMD Developer Challenge 2025 "fp8-mm" task
# benchmarked DeepSeek-shaped GEMMs at m in {1024, 6144}; the leaderboard was
# the geometric mean over 18 (m, n, k) sizes, and the paper's selector prompt
# shows 6 of them (§3.1, A.1 cites m=6144, k=512, n=4096).
# ---------------------------------------------------------------------------
_NK_PAIRS = [
    (1536, 7168), (3072, 1536), (576, 7168), (7168, 256), (7168, 2048),
    (4608, 7168), (7168, 2304), (512, 7168), (4096, 512),
]
BENCH_CONFIGS_18 = tuple((m, n, k) for m in (1024, 6144) for (n, k) in _NK_PAIRS)
# The 6-config view given to the Evolutionary Selector (paper §3.1).
BENCH_CONFIGS_6 = (
    (1024, 1536, 7168), (1024, 7168, 2048), (1024, 4096, 512),
    (6144, 1536, 7168), (6144, 7168, 2048), (6144, 4096, 512),
)


def config_key(cfg: tuple) -> str:
    m, n, k = cfg
    return f"m{m}_n{n}_k{k}"


def geomean(values) -> float:
    vals = [v for v in values if v is not None and v > 0]
    if not vals:
        return float("inf")
    return math.exp(sum(math.log(v) for v in vals) / len(vals))


@dataclasses.dataclass
class KernelRecord:
    """One population member — the unit the three LLM stages operate on."""

    rid: str                                  # "00001"-style ID
    parents: tuple                            # (base_id,) or (base_id, reference_id)
    source: str                               # the kernel source text submitted
    genome: Optional[KernelGenome]            # None if source was hand/LLM-written
    experiment: dict                          # {description, rubric, performance, innovation}
    writer_report: str = ""                   # what the writer says it actually did
    # pending | ok | compile_error | runtime_error | incorrect | failed
    #         | worker_error | quarantined
    # ("failed": the evaluation service itself errored after retries —
    #  platform-level failure, not a verdict about the kernel;
    #  "worker_error": the kernel's evaluation killed workers until the
    #  pool's requeue budget ran out; "quarantined": its content hash is
    #  blacklisted by core.integrity.Quarantine — both score inf, so
    #  selection never touches them)
    status: str = "pending"
    error: str = ""                           # platform feedback on failure
    timings_us: dict = dataclasses.field(default_factory=dict)  # config_key -> µs
    generation: int = 0

    @property
    def score(self) -> float:
        """Leaderboard metric: geometric-mean µs (lower is better)."""
        if self.status != "ok":
            return float("inf")
        return geomean(self.timings_us.values())

    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["parents"] = list(self.parents)
        d["genome"] = self.genome.to_json() if self.genome else None
        return d

    @staticmethod
    def from_dict(d: dict) -> "KernelRecord":
        d = dict(d)
        d["parents"] = tuple(d["parents"])
        d["genome"] = KernelGenome.from_json(d["genome"]) if d["genome"] else None
        return KernelRecord(**d)


class Population:
    """Ordered store of KernelRecords with lineage queries + persistence."""

    def __init__(self) -> None:
        self._records: dict[str, KernelRecord] = {}
        self._counter = 0

    # ----------------------------------------------------------- mutation
    def new_id(self) -> str:
        self._counter += 1
        return f"{self._counter:05d}"

    def add(self, rec: KernelRecord) -> KernelRecord:
        # real exceptions, not asserts: these invariants must hold under -O
        if rec.rid in self._records:
            raise ValueError(f"duplicate record id {rec.rid!r}")
        for p in rec.parents:
            if p not in self._records:
                raise ValueError(f"unknown parent {p!r} of {rec.rid!r}")
        self._records[rec.rid] = rec
        return rec

    def remove(self, rid: str) -> KernelRecord:
        """Drop a record (and roll back the id counter if it was the newest).

        Used by campaign resume to discard the in-flight kernel of a crashed
        generation so its replay re-issues the same id.  Records with
        children cannot be removed.
        """
        rec = self._records.get(rid)
        if rec is None:
            raise KeyError(rid)
        children = [r.rid for r in self if rid in r.parents]
        if children:
            raise ValueError(f"{rid!r} has children {children}")
        del self._records[rid]
        self._counter = max((int(r.rid) for r in self), default=0)
        return rec

    # ------------------------------------------------------------ queries
    def __len__(self) -> int:
        return len(self._records)

    def __iter__(self):
        return iter(self._records.values())

    def get(self, rid: str) -> KernelRecord:
        return self._records[rid]

    def ok_records(self) -> list[KernelRecord]:
        return [r for r in self if r.status == "ok"]

    def quarantined_records(self) -> list[KernelRecord]:
        """Members blacklisted by ``core.integrity.Quarantine`` (their
        evaluation killed workers): excluded from selection, surfaced to
        the designer as genomes to steer away from."""
        return [r for r in self if r.status == "quarantined"]

    def best(self) -> Optional[KernelRecord]:
        ok = self.ok_records()
        return min(ok, key=lambda r: r.score) if ok else None

    def best_per_config(self) -> dict:
        """config_key -> (rid, µs) of the per-config champion."""
        out: dict[str, tuple] = {}
        for r in self.ok_records():
            for key, t in r.timings_us.items():
                if t is not None and (key not in out or t < out[key][1]):
                    out[key] = (r.rid, t)
        return out

    def ancestors(self, rid: str) -> set:
        seen: set[str] = set()
        stack = list(self.get(rid).parents)
        while stack:
            p = stack.pop()
            if p not in seen:
                seen.add(p)
                stack.extend(self.get(p).parents)
        return seen

    def lineage_divergent(self, a: str, b: str) -> bool:
        """True when neither record is an ancestor of the other — the
        'divergent optimization path' situation the paper's selector
        rationales single out (A.1, first sample)."""
        return b not in self.ancestors(a) | {a} and a not in self.ancestors(b) | {b}

    def one_step_analysis(self, rid: str) -> dict:
        """The paper's 'one-step experiment analysis': the experiment that led
        to a record, plus its own and its parent's benchmarks.  'By
        construction, all this information will exist' (§3.3)."""
        rec = self.get(rid)
        parent = self.get(rec.parents[0]) if rec.parents else None
        return {
            "id": rec.rid,
            "experiment": rec.experiment,
            "writer_report": rec.writer_report,
            "benchmarks": rec.timings_us,
            "status": rec.status,
            "error": rec.error,
            "parent_id": parent.rid if parent else None,
            "parent_benchmarks": parent.timings_us if parent else {},
        }

    def summary_table(self) -> list[dict]:
        """The Evolutionary Selector's view: ID, parents, per-config timings
        (paper §3.1) — nothing else crosses the interface."""
        rows = []
        for r in self:
            kind = ("library" if (r.genome and r.genome.style == "library")
                    else "kernel")
            rows.append({
                "id": r.rid,
                "parents": list(r.parents),
                "kind": kind,
                "status": r.status,
                "benchmarks_us": {k: (round(v, 2) if v else v)
                                  for k, v in r.timings_us.items()},
                "score_geomean_us": (round(r.score, 2)
                                     if r.score != float("inf") else None),
            })
        return rows

    # -------------------------------------------------------- persistence
    def save(self, path) -> None:
        path = pathlib.Path(path)
        tmp = path.with_suffix(".tmp")
        payload = {
            "counter": self._counter,
            "records": [r.to_dict() for r in self],
        }
        tmp.write_text(json.dumps(payload, indent=1))
        tmp.replace(path)  # atomic

    @staticmethod
    def load(path) -> "Population":
        payload = json.loads(pathlib.Path(path).read_text())
        pop = Population()
        pop._counter = payload["counter"]
        for d in payload["records"]:
            rec = KernelRecord.from_dict(d)
            pop._records[rec.rid] = rec
        return pop
