"""The 'findings' knowledge base (paper §3: the LLM-digested hardware notes).

The paper bootstraps from an LLM-authored findings document that summarises
hardware quirks, external blog posts, and vendor manuals into a form the
Experiment Designer can consume.  This module is that document for TPU v5e,
plus the **avenue catalog**: the menu of optimization directions, each with
the MI300 avenue it descends from (paper A.2) and its TPU-native genome
edits.  The ScriptedLLM oracle draws its experiment ideas from here; a real
LLM backend receives the same text in its prompt.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

from .genome import (
    HBM_BW, LANE, MXU_BF16_FLOPS, SCALE_BLOCK, SUBLANE, VMEM_USABLE,
    KernelGenome,
)

FINDINGS_DOCUMENT = """\
# Findings: TPU v5e for block-scaled GEMM (digested hardware notes)

Target: C[bf16][M,N] = dequant(A[fp8][M,K]) @ dequant(B[fp8][K,N]),
a_scale per (row, 128-K-block), b_scale per (128x128)-block, f32 accumulate.

## Memory hierarchy
- HBM: 16 GiB @ 819 GB/s.  VMEM: 128 MiB on-chip (the LDS analogue, but
  *per-core* and compiler-pipelined rather than manually ping-ponged).
- Pallas pipelines HBM->VMEM block fetches automatically from BlockSpec
  index maps; a block whose index map output is unchanged between
  consecutive grid steps is NOT refetched.  Double-buffering means the
  *resident* working set is ~2x the declared blocks.
- VREG tiling is (8, 128): last dim must be a multiple of 128 and the
  second-minor a multiple of 8 or the layout pass inserts copies
  (the LDS-bank-conflict analogue: misalignment costs silent shuffles).

## Compute
- MXU is a 128x128x128 systolic array: matmul tile dims should be multiples
  of 128; bf16 in / f32 preferred_element_type accumulates at full rate
  (197 TFLOP/s).  An f32xf32 dot runs ~8x slower (no native f32 systolic
  pass).  fp8 has no MXU path on v5e: upcast to bf16 (exact for e4m3
  values) and keep scales separate - this is the Matrix-Core-fragment
  analogue of MI300's MFMA 32x32x16 fp8.
- VPU (vector) f32 is ~3.9 TFLOP/s: per-element dequantization on the VPU
  can dominate if applied to both operands every K-step
  ('dequant_inputs'); applying scales to the f32 accumulator once per
  128-K sub-block ('scale_acc') costs M*N*(K/128) VPU flops instead.

## Grid & pipelining
- dimension_semantics: 'parallel' axes may be reordered/partitioned by the
  compiler; the K axis carries the accumulator scratch and must be
  'arbitrary' (sequential revisiting) - the analogue of wave-level
  accumulation in registers on MI300.
- The output tile is written once on the last K step (single-writer, the
  'single-wave global write' analogue); revisiting order mn vs nm controls
  which operand is re-streamed from HBM.
- Blocked HBM traffic: A is read (N/block_n) times, B (M/block_m) times =>
  total bytes = M*K*(N/bn) + K*N*(M/bm) + 2*M*N.  Bigger output blocks cut
  traffic quadratically until VMEM is exhausted.
"""


@dataclasses.dataclass(frozen=True)
class Avenue:
    """One optimization direction: MI300 origin -> TPU-native genome edit."""

    name: str
    mi300_origin: str       # the paper-avenue this descends from (A.2)
    description: str        # what the Designer writes in its avenue list
    innovation_prior: int   # 0-100, how structurally novel the change is
    edits: Callable[[KernelGenome], list]   # genome -> [(rubric, new_genome)]


def _tile_edits(g: KernelGenome) -> list:
    out = []
    if g.style != "blocked":
        base = KernelGenome(style="blocked", block_m=128, block_n=128, block_k=128)
        return [("Re-structure as a blocked MXU kernel with 128^3 VMEM tiles, "
                 "f32 accumulator scratch, K innermost ('arbitrary').", base)]
    for attr in ("block_m", "block_n", "block_k"):
        cur = getattr(g, attr)
        for nxt in (cur * 2, cur // 2):
            if nxt < 128 or nxt > 2048:
                continue
            cand = g.replace(**{attr: nxt})
            if not cand.validate():
                out.append((
                    f"Change {attr} from {cur} to {nxt}, keeping the other tile "
                    f"dims fixed; re-check the VMEM working set stays within "
                    f"budget and all matmul dims remain multiples of {LANE}.",
                    cand))
    return out


def _grid_order_edits(g: KernelGenome) -> list:
    if g.style != "blocked":
        return []
    nxt = "nm" if g.grid_order == "mn" else "mn"
    return [(
        f"Swap the outermost grid axis from {g.grid_order!r} to {nxt!r} so the "
        f"{'B' if nxt == 'mn' else 'A'} operand is re-streamed instead; "
        "isolate the HBM-traffic effect with unchanged tile sizes.",
        g.replace(grid_order=nxt))]


def _scale_edits(g: KernelGenome) -> list:
    if g.style != "blocked":
        return []
    nxt = ("dequant_inputs" if g.scale_application == "scale_acc" else "scale_acc")
    return [(
        f"Move scale application from {g.scale_application!r} to {nxt!r}: "
        + ("dequantize A/B tiles on the VPU before each MXU dot."
           if nxt == "dequant_inputs" else
           "feed raw (exactly-representable) fp8 values to the MXU in bf16 and "
           "apply a_scale (per row) and b_scale (per column-block) to the f32 "
           "accumulator once per 128-wide K sub-block."),
        g.replace(scale_application=nxt))]


def _dtype_edits(g: KernelGenome) -> list:
    if g.style != "blocked":
        return []
    nxt = "float32" if g.compute_dtype == "bfloat16" else "bfloat16"
    return [(
        f"Switch the MXU input dtype to {nxt}: "
        + ("full-precision dots remove any bf16 rounding concern at a "
           "throughput cost." if nxt == "float32" else
           "fp8 e4m3 values are exactly representable in bf16, so the MXU "
           "fast path is numerically free."),
        g.replace(compute_dtype=nxt))]


def _ksplit_edits(g: KernelGenome) -> list:
    if g.style != "blocked":
        return []
    out = []
    for nxt in (g.k_split * 2, max(1, g.k_split // 2)):
        if nxt == g.k_split or nxt > 8:
            continue
        cand = g.replace(k_split=nxt)
        if not cand.validate():
            out.append((
                f"Set split-K factor to {nxt}: partition the K reduction over "
                f"{nxt} parallel grid slices with a separate f32 partial-sum "
                "buffer and a final reduction pass, trading an extra M*N*4-byte "
                "HBM round-trip for more parallel grid work on small-M shapes.",
                cand))
    return out


def _semantics_edits(g: KernelGenome) -> list:
    if g.style != "blocked":
        return []
    cur = g.dimension_semantics
    if cur[0] == "parallel":
        nxt = ("arbitrary", "parallel", "arbitrary")
        note = ("Constrain the outermost grid axis to sequential ('arbitrary') "
                "to force deterministic revisit order and maximise B-tile reuse "
                "in the pipeline.")
    else:
        nxt = ("parallel", "parallel", "arbitrary")
        note = ("Mark both output grid axes 'parallel' so the compiler may "
                "partition them across cores.")
    return [(note, g.replace(dimension_semantics=nxt))]


AVENUES: tuple = (
    Avenue(
        name="mxu_tiling",
        mi300_origin="Fine-tune Tile Sizes (TB_M, TB_N, TB_K)",
        description="Systematically vary VMEM tile sizes; bigger output tiles "
                    "cut HBM re-streaming quadratically until VMEM overflows.",
        innovation_prior=25,
        edits=_tile_edits,
    ),
    Avenue(
        name="grid_order",
        mi300_origin="Optimized LDS Layout / iteration order",
        description="Swap which output axis is outermost, changing which "
                    "operand is re-fetched from HBM per output tile.",
        innovation_prior=40,
        edits=_grid_order_edits,
    ),
    Avenue(
        name="scale_application",
        mi300_origin="Optimize Scale Application Loop / LDS scale caching",
        description="Apply quantization scales on the accumulator per 128-K "
                    "sub-block instead of dequantizing both operand tiles on "
                    "the VPU (or vice versa).",
        innovation_prior=70,
        edits=_scale_edits,
    ),
    Avenue(
        name="mxu_dtype",
        mi300_origin="MFMA fragment dtype selection (fp8 32x32x16)",
        description="Choose the MXU input dtype: bf16 (exact for e4m3, full "
                    "systolic rate) vs f32 (slow path).",
        innovation_prior=55,
        edits=_dtype_edits,
    ),
    Avenue(
        name="split_k",
        mi300_origin="Increase Thread Block Occupancy",
        description="Split the K reduction across parallel grid slices to "
                    "create enough independent tiles on small-M shapes "
                    "(occupancy analogue).",
        innovation_prior=85,
        edits=_ksplit_edits,
    ),
    Avenue(
        name="dimension_semantics",
        mi300_origin="Cooperative Store to Global C / wave scheduling",
        description="Adjust which grid axes the compiler may parallelise vs "
                    "iterate sequentially (pipelining/revisit order).",
        innovation_prior=60,
        edits=_semantics_edits,
    ),
)

# Static avenue ideas that the Designer lists but whose edits are covered by
# the catalog above (kept for prompt fidelity: the paper always lists ~10).
EXTRA_AVENUE_TEXTS = (
    "Pad global inputs so M/N/K are multiples of 128 before the kernel "
    "(layout-pass copy elimination; handled by the ops.py wrapper).",
    "Vectorized global loads: ensure last-dim block extents are multiples of "
    "128 lanes so HBM->VMEM DMA runs at full width.",
    "Fuse the bf16 output cast into the final K-step store instead of a "
    "separate epilogue pass.",
    "Cache scale vectors in VMEM across K-steps (BlockSpec already pins them; "
    "verify no refetch via index-map invariance).",
)
