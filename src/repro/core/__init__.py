"""The paper's primary contribution: the GPU Kernel Scientist —
an LLM-driven evolutionary loop (Selector -> Designer -> 3x Writer ->
pooled black-box Evaluation) optimizing one complex accelerator kernel,
adapted MI300/HIP -> TPU v5e/Pallas (see DESIGN.md §2).

``__all__`` is the supported public surface: the scientist loop, the
evaluation backend API (``EvalBackend`` / ``EvalPool`` / transports /
cache), the resilience toolkit, the verdict-trust layer
(``core.integrity``), and the genome/population data model.
Anything not listed here is internal and may change without notice.
"""
from .evalpool import (
    PRIORITY_CAMPAIGN, PRIORITY_PROBE, PRIORITY_URGENT,
    EvalBackend, EvalCache, EvalHandle, EvalPool,
)
from .evaluator import EvalResult, EvaluationService, estimate_us
from .events import INTEGRITY_EVENTS, WORKER_LIFECYCLE_EVENTS, EventLog
from .genome import (
    SEED_LIBRARY, SEED_MONOLITH, SEED_MXU, SEED_NAIVE, KernelGenome,
)
from .integrity import (
    CanaryController, HealthMonitor, Integrity, Quarantine, TimingAuditor,
)
from .llm import HTTPChatLLM, LLMClient, ScriptedLLM
from .population import (
    BENCH_CONFIGS_6, BENCH_CONFIGS_18, KernelRecord, Population,
)
from .resilience import (
    DEFAULT_POLICY, NO_WAIT_POLICY, CircuitBreaker, CircuitOpenError,
    CorruptTimingService, CrashService, DriftService, FlakyLLM,
    FlakyService, PoisonService, RetryPolicy, ServiceBusyError,
    TransientError, retry_call,
)
from .scientist import GenerationLog, KernelScientist
from .transport import (
    InProcessTransport, RemoteEvalError, SubprocessTransport,
    WorkerDiedError, WorkerTransport,
)

__all__ = [
    # scientist loop
    "KernelScientist", "GenerationLog",
    # evaluation backend API
    "EvalBackend", "EvalPool", "EvalCache", "EvalHandle",
    "PRIORITY_URGENT", "PRIORITY_CAMPAIGN", "PRIORITY_PROBE",
    # transports
    "WorkerTransport", "InProcessTransport", "SubprocessTransport",
    "WorkerDiedError", "RemoteEvalError",
    # evaluation platform
    "EvaluationService", "EvalResult", "estimate_us",
    # resilience
    "RetryPolicy", "retry_call", "DEFAULT_POLICY", "NO_WAIT_POLICY",
    "TransientError", "ServiceBusyError",
    "CircuitBreaker", "CircuitOpenError",
    "FlakyLLM", "FlakyService", "CrashService",
    "CorruptTimingService", "PoisonService", "DriftService",
    # verdict-trust layer
    "Integrity", "TimingAuditor", "Quarantine", "CanaryController",
    "HealthMonitor",
    # events
    "EventLog", "WORKER_LIFECYCLE_EVENTS", "INTEGRITY_EVENTS",
    # LLM clients
    "LLMClient", "ScriptedLLM", "HTTPChatLLM",
    # data model
    "KernelGenome", "KernelRecord", "Population",
    "BENCH_CONFIGS_6", "BENCH_CONFIGS_18",
    "SEED_LIBRARY", "SEED_NAIVE", "SEED_MXU", "SEED_MONOLITH",
]
