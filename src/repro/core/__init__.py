"""The paper's primary contribution: the GPU Kernel Scientist —
an LLM-driven evolutionary loop (Selector -> Designer -> 3x Writer ->
sequential black-box Evaluation) optimizing one complex accelerator kernel,
adapted MI300/HIP -> TPU v5e/Pallas (see DESIGN.md §2).
"""
from .evalpool import (  # noqa: F401
    PRIORITY_CAMPAIGN, PRIORITY_PROBE, EvalCache, EvalHandle, EvalPool,
)
from .evaluator import EvaluationService, estimate_us  # noqa: F401
from .events import EventLog  # noqa: F401
from .genome import (  # noqa: F401
    SEED_LIBRARY, SEED_MONOLITH, SEED_MXU, SEED_NAIVE, KernelGenome,
)
from .llm import HTTPChatLLM, LLMClient, ScriptedLLM  # noqa: F401
from .population import (  # noqa: F401
    BENCH_CONFIGS_6, BENCH_CONFIGS_18, KernelRecord, Population,
)
from .resilience import (  # noqa: F401
    DEFAULT_POLICY, NO_WAIT_POLICY, FlakyLLM, FlakyService, RetryPolicy,
    ServiceBusyError, TransientError, retry_call,
)
from .scientist import GenerationLog, KernelScientist  # noqa: F401
