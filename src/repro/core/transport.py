"""Worker transports for the evaluation pool (paper §3.4, distributed).

``core.evalpool.EvalPool`` routes queued submissions to N sequential-only
evaluation workers.  *How* a worker executes a submission is this module's
concern — the ``WorkerTransport`` abstraction — so the pool's queueing,
caching, and determinism logic is identical whether the workers are threads
in this process or separate Python processes on (eventually) separate hosts:

* ``InProcessTransport`` — the original behaviour: each worker is an
  ``EvaluationService`` object called directly from the pool's worker
  thread.  Zero overhead, but a segfault or ``os._exit`` in any evaluation
  kills the whole campaign.

* ``SubprocessTransport`` — each worker is a ``python -m
  repro.core.eval_worker`` child process that rebuilds its service from a
  JSON *service spec* (see :mod:`repro.core.eval_worker`) and speaks the
  wire protocol below over stdin/stdout.  A worker that crashes
  mid-benchmark takes down only itself: the transport detects the death,
  raises :class:`WorkerDiedError`, and the pool requeues the in-flight job
  — crash containment, as AutoKernel-style per-candidate isolation.

Wire protocol — length-prefixed JSONL frames
--------------------------------------------
Each frame is one JSON object encoded as UTF-8, prefixed by its byte length
in ASCII decimal plus ``\\n``, and followed by ``\\n``::

    23\\n{"frame":"heartbeat"}\\n

Parent -> child frames:
  ``init``      first frame: ``{spec, incarnation, policy, heartbeat_interval_s}``
  ``submit``    ``{job_id, source}`` — evaluate one kernel source
  ``shutdown``  drain and exit cleanly

Child -> parent frames:
  ``hello``     child is up, service built: ``{pid}``
  ``heartbeat`` emitted every ``heartbeat_interval_s`` from a side thread,
                including *during* a long evaluation — proof of process
                liveness, not of job progress
  ``result``    ``{job_id, status, error, timings_us}`` — a platform verdict
  ``error``     ``{job_id, error}`` — the child's retries were exhausted

Liveness (load-bearing for multi-day campaigns):
  * **Death** — the child's stdout hits EOF or the process exits: detected
    within one poll interval.
  * **Stall** — no frame (heartbeat or otherwise) for ``deadline_s``: the
    process is wedged (e.g. SIGSTOP, runaway native code holding the GIL);
    it is killed and declared dead.
  * **Job deadline** — optional ``job_timeout_s``: a single evaluation that
    exceeds it is treated as a stall even if heartbeats keep arriving.

All three surface as :class:`WorkerDiedError`; the pool's response —
requeue the job, respawn the worker lazily with a stepped *incarnation*
(folded into fault-injection seeds so a deterministic crash draw cannot
repeat forever) — keeps the campaign trajectory identical to a run without
deaths, because every ``EvalResult`` is a pure function of
``(platform seed, source, config)`` (the content-keyed jitter invariant).
"""
from __future__ import annotations

import copy
import itertools
import json
import os
import pathlib
import subprocess
import sys
import threading
import time
from typing import Optional

from .evaluator import EvalResult

#: Numeric RetryPolicy fields forwarded to subprocess workers (exception
#: type tuples are not serializable; the child uses the defaults).
POLICY_WIRE_FIELDS = ("max_attempts", "base_delay_s", "multiplier",
                      "max_delay_s", "jitter", "timeout_s", "seed")


class WorkerDiedError(RuntimeError):
    """The worker executing a job died or stalled past its deadline.

    Deliberately *not* a ``resilience.TransientError``: the submission's
    fate is unknown (the platform may or may not have started it), so the
    correct response is the pool's — requeue the job for any live worker —
    not an in-place blind retry on a dead route."""


class RemoteEvalError(RuntimeError):
    """A subprocess worker reported that its own retries were exhausted.

    Mirrors the in-process outcome where ``retry_call`` around
    ``service.submit`` gives up: the pool marks the submission ``failed``.
    Not retryable by the parent — the child already spent the attempt
    budget."""


# ---------------------------------------------------------------------------
# Wire protocol
# ---------------------------------------------------------------------------
def write_frame(stream, obj: dict) -> None:
    """Write one length-prefixed JSONL frame and flush."""
    data = json.dumps(obj, separators=(",", ":")).encode()
    stream.write(b"%d\n" % len(data) + data + b"\n")
    stream.flush()


def read_frame(stream) -> Optional[dict]:
    """Read one frame; ``None`` on clean EOF; ``ValueError`` on a torn or
    corrupt frame (half-written length line or truncated payload)."""
    line = stream.readline()
    if line == b"":
        return None
    try:
        n = int(line)
    except ValueError:
        raise ValueError(f"corrupt frame length {line!r}")
    payload = stream.read(n)
    if len(payload) != n:
        raise ValueError(f"truncated frame: expected {n} bytes, "
                         f"got {len(payload)}")
    stream.read(1)  # trailing newline
    try:
        return json.loads(payload)
    except json.JSONDecodeError as e:
        raise ValueError(f"corrupt frame payload: {e}")


def policy_wire_dict(policy) -> dict:
    """The serializable subset of a RetryPolicy, for the init frame."""
    return {f: getattr(policy, f) for f in POLICY_WIRE_FIELDS}


def service_spec_of(service) -> dict:
    """The JSON service spec of ``service`` (see eval_worker.build_service).

    Raises ``TypeError`` for services that cannot describe themselves —
    those can only run on the in-process transport."""
    fn = getattr(service, "service_spec", None)
    if fn is None:
        raise TypeError(
            f"{type(service).__name__} has no service_spec(); it cannot be "
            f"rebuilt inside a subprocess worker — use transport='inprocess' "
            f"or add a service_spec() method")
    return fn()


# ---------------------------------------------------------------------------
# Transports
# ---------------------------------------------------------------------------
class WorkerTransport:
    """Executes one job at a time per worker index, on behalf of the pool.

    The pool guarantees ``run(idx, ...)`` is never called concurrently for
    the same ``idx`` (worker threads are bound 1:1 to indices).  ``emitter``
    is wired by the pool to its event log."""

    kind = "abstract"
    emitter = None   # callable(event, **fields), set by the owning pool

    @property
    def num_workers(self) -> int:
        raise NotImplementedError

    def run(self, idx: int, source: str) -> EvalResult:
        raise NotImplementedError

    def worker_states(self) -> list:
        raise NotImplementedError

    def load_worker_states(self, states: list) -> None:
        raise NotImplementedError

    def respawn(self, idx: int) -> None:
        """Force worker ``idx`` to be rebuilt (the drift response from
        ``core.integrity``: a replacement worker measures clean).  Base
        implementation only records the request — transports with a real
        worker boundary override it."""
        self._emit("worker_respawn", worker=idx, transport=self.kind,
                   effect="none")

    @property
    def submissions(self) -> int:
        raise NotImplementedError

    def close(self) -> None:
        pass

    def _emit(self, event: str, **fields) -> None:
        if self.emitter is not None:
            self.emitter(event, **fields)


class InProcessTransport(WorkerTransport):
    """The original pool behaviour: workers are service objects called from
    the pool's own threads.  Exceptions propagate unchanged (the pool's
    retry policy sees ``TransientError`` / ``ServiceBusyError`` directly)."""

    kind = "inprocess"

    def __init__(self, services) -> None:
        self.services = list(services)
        if not self.services:
            raise ValueError("InProcessTransport needs at least one service")

    @property
    def num_workers(self) -> int:
        return len(self.services)

    def run(self, idx: int, source: str) -> EvalResult:
        return self.services[idx].submit(source)

    def worker_states(self) -> list:
        return [(s.state_dict() if hasattr(s, "state_dict") else None)
                for s in self.services]

    def load_worker_states(self, states: list) -> None:
        for svc, sd in zip(self.services, states):
            if sd is not None and hasattr(svc, "load_state_dict"):
                svc.load_state_dict(sd)

    def respawn(self, idx: int) -> None:
        """No process to kill in-process; delegate to the service when it
        models incarnations itself (e.g. ``DriftService.respawn``)."""
        svc_respawn = getattr(self.services[idx], "respawn", None)
        if svc_respawn is not None:
            svc_respawn()
        self._emit("worker_respawn", worker=idx, transport=self.kind,
                   effect="service" if svc_respawn is not None else "none")

    @property
    def submissions(self) -> int:
        return sum(getattr(s, "submissions", 0) for s in self.services)


class _Pending:
    __slots__ = ("event", "frame")

    def __init__(self):
        self.event = threading.Event()
        self.frame: Optional[dict] = None

    def resolve(self, frame: dict) -> None:
        self.frame = frame
        self.event.set()


class _WorkerProc:
    """One live child process plus its reader thread and liveness clock."""

    def __init__(self, proc, incarnation: int) -> None:
        self.proc = proc
        self.incarnation = incarnation
        self.pending: dict[int, _Pending] = {}
        self.last_seen = time.monotonic()
        self.hello = threading.Event()
        self.eof = False
        self._wlock = threading.Lock()

    def send(self, obj: dict) -> None:
        with self._wlock:
            write_frame(self.proc.stdin, obj)

    def reader(self) -> None:
        try:
            while True:
                frame = read_frame(self.proc.stdout)
                if frame is None:
                    break
                self.last_seen = time.monotonic()
                kind = frame.get("frame")
                if kind == "hello":
                    self.hello.set()
                elif kind in ("result", "error"):
                    pend = self.pending.pop(frame.get("job_id"), None)
                    if pend is not None:
                        pend.resolve(frame)
                # heartbeats only refresh last_seen
        except (ValueError, OSError):
            pass          # torn frame / closed pipe: treated as death below
        self.eof = True

    def kill(self) -> None:
        try:
            self.proc.kill()
        except OSError:
            pass
        try:
            self.proc.wait(timeout=5)
        except Exception:
            pass


class SubprocessTransport(WorkerTransport):
    """Each worker is a ``repro.core.eval_worker`` child process.

    Workers spawn lazily on first use and respawn (with a stepped
    incarnation) after a death; in-flight jobs of a dead worker surface as
    ``WorkerDiedError`` for the pool to requeue.  Parent-side dispatch
    counters stand in for the children's ``submissions`` accounting in
    ``state_dict`` (children are disposable; verdicts are content-pure, so
    nothing a child accumulates affects the campaign trajectory)."""

    kind = "subprocess"

    def __init__(self, specs, policy=None,
                 heartbeat_interval_s: float = 0.5,
                 deadline_s: float = 15.0,
                 job_timeout_s: Optional[float] = None,
                 spawn_timeout_s: float = 60.0,
                 poll_interval_s: float = 0.05,
                 python: Optional[str] = None) -> None:
        specs = list(specs)
        if not specs:
            raise ValueError("SubprocessTransport needs at least one spec")
        self._specs = specs
        self._policy = policy
        self.heartbeat_interval_s = heartbeat_interval_s
        self.deadline_s = deadline_s
        self.job_timeout_s = job_timeout_s
        self.spawn_timeout_s = spawn_timeout_s
        self.poll_interval_s = poll_interval_s
        self._python = python or sys.executable
        self._workers: list[Optional[_WorkerProc]] = [None] * len(specs)
        self._incarnations = [0] * len(specs)
        self._dispatched = [0] * len(specs)
        self._job_ids = itertools.count(1)
        self._closed = False

    @property
    def num_workers(self) -> int:
        return len(self._specs)

    # --------------------------------------------------------------- spawn
    def _child_env(self) -> dict:
        env = dict(os.environ)
        src = str(pathlib.Path(__file__).resolve().parents[2])
        existing = env.get("PYTHONPATH", "")
        if src not in existing.split(os.pathsep):
            env["PYTHONPATH"] = (src + os.pathsep + existing if existing
                                 else src)
        return env

    def _spawn(self, idx: int) -> _WorkerProc:
        incarnation = self._incarnations[idx]
        proc = subprocess.Popen(
            [self._python, "-m", "repro.core.eval_worker"],
            stdin=subprocess.PIPE, stdout=subprocess.PIPE,
            env=self._child_env())
        w = _WorkerProc(proc, incarnation)
        threading.Thread(target=w.reader, daemon=True,
                         name=f"evalworker-reader-{idx}").start()
        init = {"frame": "init", "worker": idx,
                "incarnation": incarnation,
                "spec": copy.deepcopy(self._specs[idx]),
                "heartbeat_interval_s": self.heartbeat_interval_s}
        if self._policy is not None:
            init["policy"] = policy_wire_dict(self._policy)
        try:
            w.send(init)
        except OSError:
            self._reap(idx, w, "died during init handshake")
        deadline = time.monotonic() + self.spawn_timeout_s
        while not w.hello.wait(self.poll_interval_s):
            if w.eof or proc.poll() is not None:
                self._reap(idx, w, "exited during startup")
            if time.monotonic() > deadline:
                self._reap(idx, w, "startup exceeded spawn_timeout_s")
        self._workers[idx] = w
        self._emit("worker_spawn", worker=idx, incarnation=incarnation,
                   pid=proc.pid, transport=self.kind)
        return w

    def _reap(self, idx: int, w: _WorkerProc, reason: str):
        """Kill + forget a worker and raise WorkerDiedError.  The next run()
        on this index respawns with a stepped incarnation."""
        w.kill()
        if self._workers[idx] is w:
            self._workers[idx] = None
        self._incarnations[idx] += 1
        self._emit("worker_died", worker=idx, incarnation=w.incarnation,
                   reason=reason, transport=self.kind)
        raise WorkerDiedError(f"worker {idx} (incarnation {w.incarnation}) "
                              f"{reason}")

    # ----------------------------------------------------------------- run
    def run(self, idx: int, source: str) -> EvalResult:
        if self._closed:
            raise RuntimeError("SubprocessTransport is closed")
        w = self._workers[idx]
        if w is None or w.eof or w.proc.poll() is not None:
            if w is not None:
                w.kill()
                self._workers[idx] = None
            w = self._spawn(idx)
        job_id = next(self._job_ids)
        pend = _Pending()
        w.pending[job_id] = pend
        self._dispatched[idx] += 1
        try:
            w.send({"frame": "submit", "job_id": job_id, "source": source})
        except OSError:
            self._reap(idx, w, "stdin closed (died before submit)")
        t0 = time.monotonic()
        while not pend.event.wait(self.poll_interval_s):
            if w.eof or w.proc.poll() is not None:
                self._reap(idx, w, "exited mid-evaluation")
            if time.monotonic() - w.last_seen > self.deadline_s:
                self._reap(idx, w, f"silent past the {self.deadline_s}s "
                                   f"heartbeat deadline")
            if (self.job_timeout_s is not None
                    and time.monotonic() - t0 > self.job_timeout_s):
                self._reap(idx, w, f"evaluation exceeded the "
                                   f"{self.job_timeout_s}s job deadline")
        frame = pend.frame
        if frame.get("frame") == "error":
            raise RemoteEvalError(frame.get("error", "unknown remote error"))
        return EvalResult(frame["status"], frame.get("error", ""),
                          frame.get("timings_us", {}))

    def respawn(self, idx: int) -> None:
        """Kill worker ``idx`` and step its incarnation; the next ``run``
        on this index spawns the replacement lazily (same path a detected
        death takes, minus the in-flight job)."""
        w = self._workers[idx]
        if w is not None:
            w.kill()
            self._workers[idx] = None
            self._incarnations[idx] += 1
        self._emit("worker_respawn", worker=idx, transport=self.kind,
                   incarnation=self._incarnations[idx], effect="process")

    # ------------------------------------------------------------ accounting
    def worker_states(self) -> list:
        return [{"submissions": n} for n in self._dispatched]

    def load_worker_states(self, states: list) -> None:
        for idx, sd in enumerate(states[:len(self._dispatched)]):
            if sd is not None:
                self._dispatched[idx] = sd.get("submissions", 0)

    @property
    def submissions(self) -> int:
        return sum(self._dispatched)

    # --------------------------------------------------------------- close
    def close(self) -> None:
        if self._closed:
            return
        self._closed = True
        for idx, w in enumerate(self._workers):
            if w is None:
                continue
            try:
                w.send({"frame": "shutdown"})
                w.proc.wait(timeout=5)
                self._emit("worker_exit", worker=idx,
                           incarnation=w.incarnation, transport=self.kind)
            except Exception:
                w.kill()
            self._workers[idx] = None


def make_transport(transport, services, retry_policy=None, options=None
                   ) -> WorkerTransport:
    """Resolve the pool's ``transport=`` argument: an instance passes
    through; ``"inprocess"``/``"subprocess"`` construct one over
    ``services`` (subprocess via their JSON service specs)."""
    if isinstance(transport, WorkerTransport):
        return transport
    if transport in (None, "inprocess", "in-process", "thread"):
        return InProcessTransport(services)
    if transport == "subprocess":
        return SubprocessTransport(
            [service_spec_of(s) for s in services],
            policy=retry_policy, **(options or {}))
    raise ValueError(f"unknown transport {transport!r} "
                     f"(expected 'inprocess' or 'subprocess')")
