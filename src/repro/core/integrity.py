"""Measurement integrity: the verdict-trust authority of the campaign.

The paper's scientist steers on "only observed timing data" — which makes a
single corrupted, drifted, or lucky-jitter verdict poisonous: it enters the
population, wins selection, and biases every later generation.  KernelBench
(Ouyang et al., 2025) documents how easily noisy or invalid measurements
inflate apparent speedups; KernelFoundry (Wiedemann et al., 2025) re-measures
candidates *before* they enter the evolutionary population for exactly this
reason.  This module is the layer between "the platform said X" and "the
population believes X":

``TimingAuditor``
    Flags statistically improbable ``ok`` verdicts — a robust z-test of the
    verdict's log-geomean against the nearest trusted ancestor (plus
    "no trusted lineage" for seeds, which are always re-measured, the
    KernelFoundry rule) — and resolves flagged verdicts with a deterministic
    re-measure **quorum**: ``quorum_k`` salted resubmissions of the same
    kernel.  A salt is a trailing comment, so the genome (and therefore the
    cost-model timing) is unchanged while the content hash — the jitter key —
    differs, giving independent noise draws that are still a pure function of
    (platform seed, source, salt).  The quorum is content-keyed end to end:
    ``workers=N`` stays trajectory-identical, samples land in the eval cache,
    and a campaign killed mid-quorum replays the completed samples as cache
    hits.  A MAD test of the original against the sample median decides
    whether the original verdict is *confirmed* (kept bit-for-bit) or
    *corrected* (replaced by the per-config sample medians).

``Quarantine``
    Content-hash blacklist of kernels that kill or stall workers.  Each
    ``WorkerDiedError`` against a source hash counts one death; at
    ``after_k`` deaths the hash is quarantined — further submissions resolve
    instantly to a ``quarantined`` verdict without touching a worker, so a
    poison kernel evolution keeps rediscovering costs K worker deaths total,
    not ``max_requeues`` per rediscovery.  ``selector`` never picks
    quarantined members (their score is inf) and ``designer`` is told about
    them in its prompt context.

``CanaryController``
    Per-worker drift detection.  Every ``interval`` generations the scientist
    runs the same known-timing sentinel kernel directly on **each** worker
    (``EvalPool.run_direct`` — bypassing queue and cache, so the worker
    really measures it).  The first canary establishes the trusted reference;
    a worker whose canary deviates by more than ``tolerance`` is drifted: its
    verdicts from the current generation are cache-invalidated and
    re-measured, and the worker is respawned (stepped incarnation).

``HealthMonitor``
    The campaign watchdog: wall-clock / submission budgets that stop the
    loop at a generation boundary (``budget_stop`` event) instead of
    overrunning, plus a periodic ``health`` snapshot streamed to
    ``events.jsonl`` after every generation.

``Integrity``
    The facade the scientist owns.  Every knob defaults to *off* — a default
    ``Integrity()`` changes nothing — and all live state (audit ledger
    counters, quarantine set, breaker states, canary reference/schedule,
    consumed wall-clock) round-trips through ``state_dict`` /
    ``load_state_dict``, persisted in the campaign ``state.json`` under
    ``_STATE_SCHEMA >= 3`` so kill-and-resume keeps the trajectory-identity
    contract.

The circuit breakers themselves (LLM + eval backend) live in
``core.resilience.CircuitBreaker``; ``Integrity`` owns their instances and
persistence.
"""
from __future__ import annotations

import math
import statistics
import threading
import time
from typing import Optional

from .population import geomean
from .resilience import CircuitBreaker


class TimingAuditor:
    """Flag improbable ``ok`` verdicts and resolve them by salted quorum.

    ``flag`` is the statistical gate (robust z vs. the nearest trusted
    ancestor's geomean); ``salted`` produces the quorum sample sources;
    ``merge`` is the MAD decision between confirming the original verdict
    and correcting it to the per-config sample medians.  Everything is
    deterministic: no RNG, no wall clock."""

    def __init__(self, quorum_k: int = 3, z_max: float = 3.0,
                 sigma_floor: float = 0.25, mad_z: float = 5.0,
                 mad_floor: float = 0.02) -> None:
        if quorum_k < 1:
            raise ValueError("quorum_k must be >= 1")
        self.quorum_k = quorum_k
        self.z_max = z_max
        #: stand-in log-sigma for single-point lineage comparisons: a real
        #: optimization step moves the geomean by ~2x at most (z ~ 2.8),
        #: while a corrupted verdict at 4-5x lands well past z_max.
        self.sigma_floor = sigma_floor
        self.mad_z = mad_z
        self.mad_floor = mad_floor
        # audit ledger counters (persisted; the events log holds the detail)
        self.flags = 0
        self.quorums = 0
        self.corrected = 0

    # ------------------------------------------------------------- flagging
    def flag(self, geomean_us: float,
             baseline_us: Optional[float]) -> Optional[str]:
        """Reason string when the verdict needs a quorum, else ``None``.

        ``baseline_us`` is the geomean of the nearest trusted (already
        audited, status ok) ancestor; ``None`` means the kernel has no
        trusted lineage — seeds and orphans — which are always re-measured
        before the population may trust them."""
        if not (geomean_us > 0) or geomean_us == float("inf"):
            return "non-positive geomean"
        if baseline_us is None or not (baseline_us > 0):
            return "no trusted lineage baseline (seed or orphan)"
        z = abs(math.log(geomean_us) - math.log(baseline_us)) \
            / self.sigma_floor
        if z > self.z_max:
            return (f"z={z:.2f} vs trusted lineage baseline "
                    f"(|ln {geomean_us:.1f} - ln {baseline_us:.1f}| / "
                    f"{self.sigma_floor})")
        return None

    # -------------------------------------------------------------- quorum
    @staticmethod
    def salted(source: str, sample: int) -> str:
        """Sample ``sample`` of the re-measure quorum for ``source``.

        The salt is a trailing comment: the module still ``exec``s to the
        identical kernel (same GENOME, same cost-model timing) but its
        sha256 — the platform's jitter key and the cache key — changes, so
        each sample is an independent, *deterministic*, cacheable draw."""
        return source + f"\n# integrity-quorum sample {sample}\n"

    def merge(self, original, samples: list):
        """Resolve a flagged verdict against its quorum samples.

        Returns ``(final_result, corrected)``.  The decision is a MAD test
        in log space: if the original geomean sits within ``mad_z`` robust
        sigmas of the sample median it is *confirmed* (kept unchanged —
        the original is itself a legitimate draw); otherwise it is
        *corrected* to the per-config medians of the samples.  With no
        usable samples the original stands."""
        from .evaluator import EvalResult
        self.quorums += 1
        samples = [s for s in samples
                   if s is not None and s.status == "ok" and s.timings_us]
        if not samples:
            return original, False
        ln_gs = sorted(math.log(geomean(s.timings_us.values()))
                       for s in samples)
        med_ln = statistics.median(ln_gs)
        mad = statistics.median(abs(g - med_ln) for g in ln_gs)
        sigma = max(mad * 1.4826, self.mad_floor)
        ln_orig = math.log(geomean(original.timings_us.values()))
        if abs(ln_orig - med_ln) <= self.mad_z * sigma:
            return original, False
        self.corrected += 1
        keys = set().union(*(s.timings_us.keys() for s in samples))
        timings = {k: statistics.median(s.timings_us[k] for s in samples
                                        if k in s.timings_us)
                   for k in sorted(keys)}
        return EvalResult("ok", original.error, timings), True

    # ------------------------------------------------------------- persist
    def state_dict(self) -> dict:
        return {"flags": self.flags, "quorums": self.quorums,
                "corrected": self.corrected}

    def load_state_dict(self, d: dict) -> None:
        self.flags = d.get("flags", 0)
        self.quorums = d.get("quorums", 0)
        self.corrected = d.get("corrected", 0)


class Quarantine:
    """Content-hash blacklist of worker-killing kernels.

    Thread-safe: ``EvalPool`` worker threads call ``record_death`` /
    ``blocked`` concurrently with the scientist's submissions.  Keys are
    the same sha256 content addresses the eval cache uses."""

    def __init__(self, after_k: int = 3) -> None:
        if after_k < 1:
            raise ValueError("after_k must be >= 1")
        self.after_k = after_k
        self._deaths: dict[str, int] = {}
        self._reasons: dict[str, str] = {}
        self._lock = threading.Lock()

    def record_death(self, key: str, reason: str = "") -> int:
        """Count one worker death against ``key``; returns the new total."""
        with self._lock:
            n = self._deaths[key] = self._deaths.get(key, 0) + 1
            if n >= self.after_k and key not in self._reasons:
                self._reasons[key] = (reason or "killed its worker "
                                      f"{n} times")
            return n

    def blocked(self, key: str) -> Optional[str]:
        """The quarantine reason for ``key``, or ``None`` if admissible."""
        with self._lock:
            return self._reasons.get(key)

    def deaths(self, key: str) -> int:
        with self._lock:
            return self._deaths.get(key, 0)

    def __len__(self) -> int:
        with self._lock:
            return len(self._reasons)

    def state_dict(self) -> dict:
        with self._lock:
            return {"after_k": self.after_k, "deaths": dict(self._deaths),
                    "reasons": dict(self._reasons)}

    def load_state_dict(self, d: dict) -> None:
        with self._lock:
            self._deaths = dict(d.get("deaths", {}))
            self._reasons = dict(d.get("reasons", {}))


class CanaryController:
    """Schedule + reference for the per-worker sentinel submissions.

    The sentinel source is fixed for the whole campaign (constant content
    hash, so the content-keyed platform answers with constant timings on a
    healthy worker — the reference comparison is exact up to drift).  The
    first measurement establishes the reference; ``check`` classifies each
    subsequent one."""

    def __init__(self, interval: int = 1, tolerance: float = 0.25) -> None:
        if interval < 1:
            raise ValueError("interval must be >= 1 generation")
        if tolerance <= 0:
            raise ValueError("tolerance must be positive")
        self.interval = interval
        self.tolerance = tolerance
        self.reference_us: Optional[float] = None
        self.runs = 0
        self.drifts = 0
        self._sentinel: Optional[str] = None

    def due(self, generation: int) -> bool:
        return generation % self.interval == 0

    def sentinel_source(self) -> str:
        if self._sentinel is None:
            from . import codegen
            from .genome import SEED_MXU
            self._sentinel = codegen.render_source(
                SEED_MXU, "integrity canary: known-timing sentinel kernel")
        return self._sentinel

    def check(self, geomean_us: Optional[float]) -> str:
        """Classify one canary measurement: ``baseline`` (first trusted
        measurement), ``ok``, or ``drift``."""
        self.runs += 1
        if geomean_us is None or not (geomean_us > 0):
            self.drifts += 1
            return "drift"
        if self.reference_us is None:
            self.reference_us = geomean_us
            return "baseline"
        if abs(math.log(geomean_us / self.reference_us)) \
                > math.log1p(self.tolerance):
            self.drifts += 1
            return "drift"
        return "ok"

    def state_dict(self) -> dict:
        return {"interval": self.interval, "tolerance": self.tolerance,
                "reference_us": self.reference_us, "runs": self.runs,
                "drifts": self.drifts}

    def load_state_dict(self, d: dict) -> None:
        self.reference_us = d.get("reference_us")
        self.runs = d.get("runs", 0)
        self.drifts = d.get("drifts", 0)


class HealthMonitor:
    """Wall-clock / submission budgets + periodic health snapshots.

    Budgets are enforced at generation boundaries (the scientist checks
    before starting a generation) so the campaign stops cleanly with its
    state persisted, never mid-drain.  Consumed wall-clock is accumulated
    across resumes: ``state_dict`` folds the running segment in, and a
    resumed campaign continues the budget where the killed one left off."""

    def __init__(self, max_wall_clock_s: Optional[float] = None,
                 max_submissions: Optional[int] = None,
                 clock=time.monotonic) -> None:
        self.max_wall_clock_s = max_wall_clock_s
        self.max_submissions = max_submissions
        self._clock = clock
        self._accumulated_s = 0.0
        self._t0: Optional[float] = None

    def start(self) -> None:
        if self._t0 is None:
            self._t0 = self._clock()

    @property
    def elapsed_s(self) -> float:
        running = (self._clock() - self._t0) if self._t0 is not None else 0.0
        return self._accumulated_s + running

    def budget_exceeded(self, submissions: int) -> Optional[str]:
        if (self.max_submissions is not None
                and submissions >= self.max_submissions):
            return (f"submission budget exhausted "
                    f"({submissions}/{self.max_submissions})")
        if (self.max_wall_clock_s is not None
                and self.elapsed_s >= self.max_wall_clock_s):
            return (f"wall-clock budget exhausted "
                    f"({self.elapsed_s:.1f}s/{self.max_wall_clock_s}s)")
        return None

    def snapshot(self, events, **fields) -> None:
        """Stream one ``health`` event (the watchdog's periodic heartbeat)."""
        events.emit("health", elapsed_s=round(self.elapsed_s, 3),
                    budget_wall_clock_s=self.max_wall_clock_s,
                    budget_submissions=self.max_submissions, **fields)

    def state_dict(self) -> dict:
        return {"elapsed_s": round(self.elapsed_s, 3)}

    def load_state_dict(self, d: dict) -> None:
        self._accumulated_s = d.get("elapsed_s", 0.0)
        self._t0 = None        # restarted by the next run()


class Integrity:
    """Facade bundling the verdict-trust components for one campaign.

    Every knob defaults to *off*: ``Integrity()`` builds no components and
    the scientist behaves exactly as before.  Components are enabled
    independently —

    * ``quorum_k > 0``           → :class:`TimingAuditor`
    * ``quarantine_after > 0``   → :class:`Quarantine` (wired into the pool)
    * ``canary_interval > 0``    → :class:`CanaryController`
    * ``budget_*`` set           → :class:`HealthMonitor`
    * ``breaker_failures > 0``   → LLM + eval :class:`CircuitBreaker` pair
    """

    def __init__(self, quorum_k: int = 0, z_max: float = 3.0,
                 sigma_floor: float = 0.25, mad_z: float = 5.0,
                 quarantine_after: int = 0,
                 canary_interval: int = 0, canary_tolerance: float = 0.25,
                 budget_submissions: Optional[int] = None,
                 budget_wall_clock_s: Optional[float] = None,
                 breaker_failures: int = 0, breaker_cooldown: int = 8,
                 clock=time.monotonic) -> None:
        self.config = {
            "quorum_k": quorum_k, "z_max": z_max,
            "sigma_floor": sigma_floor, "mad_z": mad_z,
            "quarantine_after": quarantine_after,
            "canary_interval": canary_interval,
            "canary_tolerance": canary_tolerance,
            "budget_submissions": budget_submissions,
            "budget_wall_clock_s": budget_wall_clock_s,
            "breaker_failures": breaker_failures,
            "breaker_cooldown": breaker_cooldown,
        }
        self.auditor = (TimingAuditor(quorum_k=quorum_k, z_max=z_max,
                                      sigma_floor=sigma_floor, mad_z=mad_z)
                        if quorum_k else None)
        self.quarantine = (Quarantine(after_k=quarantine_after)
                           if quarantine_after else None)
        self.canary = (CanaryController(interval=canary_interval,
                                        tolerance=canary_tolerance)
                       if canary_interval else None)
        self.health = (HealthMonitor(max_wall_clock_s=budget_wall_clock_s,
                                     max_submissions=budget_submissions,
                                     clock=clock)
                       if (budget_submissions is not None
                           or budget_wall_clock_s is not None) else None)
        self.llm_breaker = (CircuitBreaker(
            failure_threshold=breaker_failures,
            cooldown_calls=breaker_cooldown, name="llm")
            if breaker_failures else None)
        self.eval_breaker = (CircuitBreaker(
            failure_threshold=breaker_failures,
            cooldown_calls=breaker_cooldown, name="eval")
            if breaker_failures else None)

    @property
    def enabled(self) -> bool:
        return any(c is not None for c in
                   (self.auditor, self.quarantine, self.canary, self.health,
                    self.llm_breaker))

    def state_dict(self) -> dict:
        parts = {"config": dict(self.config)}
        for name in ("auditor", "quarantine", "canary", "health",
                     "llm_breaker", "eval_breaker"):
            comp = getattr(self, name)
            parts[name] = comp.state_dict() if comp is not None else None
        return parts

    def load_state_dict(self, d: dict) -> None:
        if not d:
            return
        for name in ("auditor", "quarantine", "canary", "health",
                     "llm_breaker", "eval_breaker"):
            comp = getattr(self, name)
            if comp is not None and d.get(name) is not None:
                comp.load_state_dict(d[name])
