"""KernelGenome — the typed search space the Kernel Scientist explores.

The paper's LLM Kernel Writer edits HIP source directly.  Our writer renders
a *genome* into real Pallas source (see ``writer.render_source``), and the
EvaluationService compiles that **source text**, so the loop is genuinely
code-in-the-loop: a real LLM backend can emit arbitrary kernel source through
the same interface, and compile errors become black-box feedback exactly as
on the competition platform.

Each genome axis corresponds to an optimization avenue the paper's Experiment
Designer explored on MI300, re-derived for the TPU memory hierarchy
(HBM -> VMEM -> VREG, 128x128 MXU).  See ``knowledge.AVENUES`` for the
per-avenue MI300 -> TPU mapping.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Any

# --- TPU v5e hardware constants (also used by the analytic cost model) -----
MXU_BF16_FLOPS = 197e12       # peak bf16 FLOP/s per chip
MXU_F32_FLOPS = MXU_BF16_FLOPS / 8.0   # fp32 fallback path
VPU_F32_FLOPS = 3.9e12        # vector unit, f32
HBM_BW = 819e9                # bytes/s
VMEM_BYTES = 128 * 1024 * 1024
VMEM_USABLE = int(VMEM_BYTES * 0.75)  # compiler/scoreboard headroom
LANE = 128                    # last-dim register tiling
SUBLANE = 8

SCALE_BLOCK = 128             # quantization block (AMD challenge spec)

_DTYPE_BYTES = {"float8_e4m3fn": 1, "int8": 1, "bfloat16": 2, "float32": 4}


@dataclasses.dataclass(frozen=True)
class KernelGenome:
    """One point in the scaled-GEMM kernel design space."""

    style: str = "blocked"            # "library" | "naive" | "blocked"
    block_m: int = 256
    block_n: int = 256
    block_k: int = 256
    grid_order: str = "mn"            # outermost output axis: "mn" | "nm"
    scale_application: str = "scale_acc"   # | "dequant_inputs"
    compute_dtype: str = "bfloat16"   # MXU input dtype: "bfloat16" | "float32"
    acc_dtype: str = "float32"
    out_dtype: str = "bfloat16"
    dimension_semantics: tuple = ("parallel", "parallel", "arbitrary")
    # Beyond-paper axes added during hillclimbing:
    k_split: int = 1                  # split-K reduction factor (1 = off)

    # ----------------------------------------------------------------- utils
    def to_json(self) -> str:
        d = dataclasses.asdict(self)
        d["dimension_semantics"] = list(self.dimension_semantics)
        return json.dumps(d, sort_keys=True)

    @staticmethod
    def from_json(s: str) -> "KernelGenome":
        d = json.loads(s)
        d["dimension_semantics"] = tuple(d["dimension_semantics"])
        return KernelGenome(**d)

    def replace(self, **kw: Any) -> "KernelGenome":
        return dataclasses.replace(self, **kw)

    # ------------------------------------------------------------ validation
    def storage_bytes(self) -> int:
        return 1  # fp8 storage (challenge spec)

    def vmem_bytes(self) -> int:
        """Pipelined VMEM working set: 2x (double-buffered) in/out blocks +
        accumulator scratch.  The naive style holds the whole problem, which
        the caller checks against the actual (M, K, N)."""
        if self.style != "blocked":
            return 0
        sb = self.storage_bytes()
        n_sub = self.block_k // SCALE_BLOCK
        in_blocks = (
            self.block_m * self.block_k * sb          # A tile
            + self.block_k * self.block_n * sb        # B tile
            + self.block_m * n_sub * 4                # a_scale tile
            + n_sub * (self.block_n // SCALE_BLOCK) * 4
        )
        out_block = self.block_m * self.block_n * _DTYPE_BYTES[self.out_dtype]
        acc = self.block_m * self.block_n * _DTYPE_BYTES[self.acc_dtype]
        return 2 * (in_blocks + out_block) + acc

    def validate(self) -> list[str]:
        """Static (pre-submission) legality check.  Returns problem list; the
        EvaluationService independently rejects at 'compile' time, so an LLM
        writer that skips this check still gets platform feedback."""
        errs = []
        if self.style not in ("library", "naive", "blocked"):
            errs.append(f"unknown style {self.style!r}")
        if self.style == "blocked":
            for name, b in (("block_m", self.block_m), ("block_n", self.block_n),
                            ("block_k", self.block_k)):
                if b <= 0:
                    errs.append(f"{name}={b} must be positive")
            if self.block_k % SCALE_BLOCK:
                errs.append(f"block_k={self.block_k} must divide by {SCALE_BLOCK}")
            if self.block_n % SCALE_BLOCK:
                errs.append(f"block_n={self.block_n} must divide by {SCALE_BLOCK}")
            if self.vmem_bytes() > VMEM_USABLE:
                errs.append(
                    f"VMEM working set {self.vmem_bytes()/2**20:.1f} MiB exceeds "
                    f"{VMEM_USABLE/2**20:.0f} MiB usable")
            if self.grid_order not in ("mn", "nm"):
                errs.append(f"grid_order={self.grid_order!r}")
            if self.scale_application not in ("scale_acc", "dequant_inputs"):
                errs.append(f"scale_application={self.scale_application!r}")
            if self.compute_dtype not in ("bfloat16", "float32"):
                errs.append(f"compute_dtype={self.compute_dtype!r}")
            if self.k_split < 1 or self.k_split > 16:
                errs.append(f"k_split={self.k_split} out of range [1, 16]")
            if len(self.dimension_semantics) != 3:
                errs.append("dimension_semantics must have 3 entries")
            elif self.dimension_semantics[2] != "arbitrary":
                errs.append("K grid axis carries the accumulator: must be 'arbitrary'")
        return errs

    # --------------------------------------------------------------- pretty
    def describe(self) -> str:
        if self.style == "library":
            return "library path: XLA jnp.dot after full f32 dequantization"
        if self.style == "naive":
            return "naive: single-program kernel, whole problem resident in VMEM"
        return (
            f"blocked {self.block_m}x{self.block_n}x{self.block_k} "
            f"grid={self.grid_order} k_split={self.k_split} "
            f"scales={self.scale_application} mxu={self.compute_dtype}"
        )


# Paper §3 seed set, TPU-native (see DESIGN.md §4):
#  - the provided library implementation (paper: "basic PyTorch"),
#  - a direct translation: correct but unoptimized — f32 math, per-tile
#    dequantization, minimal square tiles (paper: "~6x slower than PyTorch"),
#  - the first working MXU kernel (paper: "Matrix Cores gift").
SEED_LIBRARY = KernelGenome(style="library")
SEED_NAIVE = KernelGenome(
    style="blocked", block_m=128, block_n=128, block_k=128,
    compute_dtype="float32", scale_application="dequant_inputs",
    dimension_semantics=("arbitrary", "arbitrary", "arbitrary"),
)
SEED_MXU = KernelGenome(style="blocked", block_m=128, block_n=128, block_k=128)
# A single-program whole-problem kernel (VMEM-OOM on real sizes — exercised
# by tests of the platform's compile-error feedback path).
SEED_MONOLITH = KernelGenome(style="naive")
