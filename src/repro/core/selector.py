"""Stage 1 — LLM Evolutionary Selector (paper §3.1).

From the population (IDs, parent lineage, per-config benchmark timings) the
LLM picks a *Base* for the next experiment and a *Reference* "chosen for its
ability to help in analysing experiments".  There is deliberately no
hand-built selection mechanism beyond this (the paper relies on the LLM's
multi-objective judgement); the stage only validates the reply.
"""
from __future__ import annotations

import dataclasses

from . import prompts
from .llm import LLMClient
from .population import Population


@dataclasses.dataclass(frozen=True)
class Selection:
    basis_code: str
    basis_reference: str
    rationale: str


def select(population: Population, llm: LLMClient,
           task_text: str = prompts.TASK_TEXT) -> Selection:
    rows = population.summary_table()
    prompt = prompts.selector_prompt(rows, task_text)
    reply = prompts.extract_reply_json(llm.complete(prompt))

    basis = str(reply["basis_code"])
    reference = str(reply["basis_reference"])
    known = {r["id"] for r in rows}
    if basis not in known:
        raise ValueError(f"selector returned unknown basis {basis!r}")
    if population.get(basis).status != "ok":
        raise ValueError(f"selector basis {basis!r} has no benchmarks")
    if reference not in known:
        # tolerate a hallucinated reference: fall back to the basis' parent
        parents = population.get(basis).parents
        reference = parents[0] if parents else basis
    return Selection(basis, reference, str(reply.get("rationale", "")))
