"""Stage 1 — LLM Evolutionary Selector (paper §3.1).

From the population (IDs, parent lineage, per-config benchmark timings) the
LLM picks a *Base* for the next experiment and a *Reference* "chosen for its
ability to help in analysing experiments".  There is deliberately no
hand-built selection mechanism beyond this (the paper relies on the LLM's
multi-objective judgement); the stage only validates the reply.
"""
from __future__ import annotations

import dataclasses

from . import prompts
from .llm import LLMClient
from .population import Population


@dataclasses.dataclass(frozen=True)
class Selection:
    basis_code: str
    basis_reference: str
    rationale: str


def select(population: Population, llm: LLMClient,
           task_text: str = prompts.TASK_TEXT) -> Selection:
    rows = population.summary_table()
    prompt = prompts.selector_prompt(rows, task_text)
    reply = prompts.extract_reply_json(llm.complete(prompt))

    basis = str(reply["basis_code"])
    reference = str(reply["basis_reference"])
    known = {r["id"] for r in rows}
    if basis not in known:
        raise ValueError(f"selector returned unknown basis {basis!r}")
    if population.get(basis).status != "ok":
        raise ValueError(f"selector basis {basis!r} has no benchmarks")
    if (reference not in known
            or population.get(reference).status == "quarantined"):
        # tolerate a hallucinated reference — and refuse a quarantined one
        # (a worker-killing kernel has no timings worth comparing against):
        # fall back to the basis' parent
        parents = population.get(basis).parents
        reference = parents[0] if parents else basis
    return Selection(basis, reference, str(reply.get("rationale", "")))


def fallback_select(population: Population) -> Selection:
    """Deterministic rule-based selection when the LLM selector stays
    unusable after retries: best-scoring editable kernel as the Base, its
    direct parent (else the best other member) as the Reference.  Mirrors
    the paper's A.1 rule (ii) so a degraded generation still advances the
    campaign instead of aborting it."""
    ok = population.ok_records()
    if not ok:
        raise RuntimeError(
            "cannot select: no successfully evaluated kernels in the "
            "population (every submission so far failed)")
    editable = [r for r in ok
                if not (r.genome and r.genome.style == "library")]
    basis = min(editable or ok, key=lambda r: (r.score, r.rid))
    others = sorted(r.rid for r in ok if r.rid != basis.rid)
    reference = (basis.parents[0] if basis.parents
                 else (others[0] if others else basis.rid))
    return Selection(
        basis.rid, reference,
        f"(rule-based fallback after LLM failures) Run {basis.rid} has the "
        f"lowest geometric-mean benchmark score among editable kernels; run "
        f"{reference} is its direct parent or the next-best evaluated "
        f"member, giving the designer the closest useful comparison point.")
