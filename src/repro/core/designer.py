"""Stage 2 — LLM Experiment Designer (paper §3.2).

Given the Base code plus assimilated knowledge (the findings document), the
LLM produces 10 optimization *avenues*, then 5 *experiment plans* each with a
description, a rubric, a predicted performance-benefit range ``[lo, hi]`` and
an ``innovation`` score.  Three plans are then chosen **without replacement**
by the paper's fixed rule: (i) most innovative, (ii) highest maximum
performance, (iii) highest minimum performance.
"""
from __future__ import annotations

import json

from . import knowledge, prompts
from .genome import KernelGenome
from .llm import LLMClient
from .population import Population


def _candidate_edits(base_genome: KernelGenome | None) -> list:
    """Machine-readable edit suggestions shipped with the findings document
    (the digested-manual part of the knowledge base).  The LLM may use,
    modify, or ignore them."""
    g = base_genome or KernelGenome(style="library")
    cands = []
    for avenue in knowledge.AVENUES:
        for rubric, new_g in avenue.edits(g):
            base_d = json.loads(g.to_json())
            new_d = json.loads(new_g.to_json())
            edit = {k: v for k, v in new_d.items() if base_d.get(k) != v}
            cands.append({
                "avenue": avenue.name,
                "mi300_origin": avenue.mi300_origin,
                "rubric": rubric,
                "genome_edit": edit,
                "innovation_prior": avenue.innovation_prior,
            })
    return cands


def design(population: Population, basis_id: str, reference_id: str,
           llm: LLMClient, task_text: str = prompts.TASK_TEXT) -> list:
    """Returns the 5 experiment plans (dicts), unpicked."""
    base = population.get(basis_id)
    base_analysis = population.one_step_analysis(basis_id)
    base_analysis["genome"] = base.genome.to_json() if base.genome else None
    reference_analysis = population.one_step_analysis(reference_id)

    avenue_texts = ([a.description for a in knowledge.AVENUES]
                    + list(knowledge.EXTRA_AVENUE_TEXTS))
    # integrity context: genomes whose evaluation killed workers — the
    # designer is told so it stops proposing equivalents of them
    quarantined = [{"id": r.rid,
                    "genome": r.genome.to_json() if r.genome else None,
                    "error": r.error}
                   for r in population.quarantined_records()] or None
    prompt = prompts.designer_prompt(
        base_analysis, reference_analysis, base.source,
        knowledge.FINDINGS_DOCUMENT, avenue_texts,
        _candidate_edits(base.genome), task_text,
        quarantined=quarantined)
    reply = prompts.extract_reply_json(llm.complete(prompt))

    plans = list(reply["experiments"])
    validate_plans(plans)
    return plans[:5]


def validate_plans(plans: list) -> list:
    """Schema-check designer output, raising ``ValueError`` on violations.

    Real exceptions, not asserts: ``assert`` vanishes under ``python -O``,
    which would silently admit malformed plans into the loop.  A raised
    ``ValueError`` is retryable — the scientist re-asks the LLM, then falls
    back to :func:`fallback_design`.
    """
    if len(plans) < 1:
        raise ValueError("designer produced no experiment plans")
    for p in plans:
        missing = {"description", "rubric", "performance",
                   "innovation"} - set(p)
        if missing:
            raise ValueError(f"plan missing fields {sorted(missing)}: {p!r}")
        try:
            lo, hi = p["performance"]
        except (TypeError, ValueError):
            raise ValueError(f"performance must be a [lo, hi] pair: {p!r}")
        if lo > hi:
            raise ValueError(f"performance range inverted ({lo} > {hi}): {p!r}")
        if not 0 <= int(p["innovation"]) <= 100:
            raise ValueError(f"innovation outside [0, 100]: {p!r}")
    return plans


def fallback_design(population: Population, basis_id: str) -> list:
    """Deterministic rule-based plans when the LLM designer stays unusable
    after retries: take the knowledge base's own candidate edits (one per
    avenue first, for diversity), with performance ranges and innovation
    scores from the avenue priors.  Keeps the generation alive instead of
    aborting the campaign."""
    def plan(cand):
        prior = int(cand["innovation_prior"])
        return {
            "description": ("[fallback/" + cand["avenue"] + "] "
                            + cand["rubric"].splitlines()[0]),
            "rubric": cand["rubric"],
            "performance": [0, max(5, prior // 2)],
            "innovation": prior,
            "genome_edit": cand["genome_edit"],
        }

    cands = _candidate_edits(population.get(basis_id).genome)
    plans, seen_avenues = [], set()
    for cand in cands:                        # one plan per avenue first
        if len(plans) < 5 and cand["avenue"] not in seen_avenues:
            seen_avenues.add(cand["avenue"])
            plans.append(plan(cand))
    for cand in cands:                        # backfill to 5 if few avenues
        if len(plans) == 5:
            break
        if all(p["rubric"] != cand["rubric"] for p in plans):
            plans.append(plan(cand))
    return validate_plans(plans)


def pick3(plans: list) -> list:
    """The paper's fixed choose-3-of-5 rule, without replacement:
    (i) most innovative; (ii) highest max performance; (iii) highest min
    performance."""
    remaining = list(plans)
    chosen = []
    for keyfn in (lambda p: p["innovation"],
                  lambda p: p["performance"][1],
                  lambda p: p["performance"][0]):
        if not remaining:
            break
        best = max(remaining, key=keyfn)
        chosen.append(best)
        remaining.remove(best)
    return chosen
