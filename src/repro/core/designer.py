"""Stage 2 — LLM Experiment Designer (paper §3.2).

Given the Base code plus assimilated knowledge (the findings document), the
LLM produces 10 optimization *avenues*, then 5 *experiment plans* each with a
description, a rubric, a predicted performance-benefit range ``[lo, hi]`` and
an ``innovation`` score.  Three plans are then chosen **without replacement**
by the paper's fixed rule: (i) most innovative, (ii) highest maximum
performance, (iii) highest minimum performance.
"""
from __future__ import annotations

import json

from . import knowledge, prompts
from .genome import KernelGenome
from .llm import LLMClient
from .population import Population


def _candidate_edits(base_genome: KernelGenome | None) -> list:
    """Machine-readable edit suggestions shipped with the findings document
    (the digested-manual part of the knowledge base).  The LLM may use,
    modify, or ignore them."""
    g = base_genome or KernelGenome(style="library")
    cands = []
    for avenue in knowledge.AVENUES:
        for rubric, new_g in avenue.edits(g):
            base_d = json.loads(g.to_json())
            new_d = json.loads(new_g.to_json())
            edit = {k: v for k, v in new_d.items() if base_d.get(k) != v}
            cands.append({
                "avenue": avenue.name,
                "mi300_origin": avenue.mi300_origin,
                "rubric": rubric,
                "genome_edit": edit,
                "innovation_prior": avenue.innovation_prior,
            })
    return cands


def design(population: Population, basis_id: str, reference_id: str,
           llm: LLMClient, task_text: str = prompts.TASK_TEXT) -> list:
    """Returns the 5 experiment plans (dicts), unpicked."""
    base = population.get(basis_id)
    base_analysis = population.one_step_analysis(basis_id)
    base_analysis["genome"] = base.genome.to_json() if base.genome else None
    reference_analysis = population.one_step_analysis(reference_id)

    avenue_texts = ([a.description for a in knowledge.AVENUES]
                    + list(knowledge.EXTRA_AVENUE_TEXTS))
    prompt = prompts.designer_prompt(
        base_analysis, reference_analysis, base.source,
        knowledge.FINDINGS_DOCUMENT, avenue_texts,
        _candidate_edits(base.genome), task_text)
    reply = prompts.extract_reply_json(llm.complete(prompt))

    plans = list(reply["experiments"])
    if len(plans) < 1:
        raise ValueError("designer produced no experiment plans")
    for p in plans:
        lo, hi = p["performance"]
        assert lo <= hi, p
        assert 0 <= int(p["innovation"]) <= 100, p
    return plans[:5]


def pick3(plans: list) -> list:
    """The paper's fixed choose-3-of-5 rule, without replacement:
    (i) most innovative; (ii) highest max performance; (iii) highest min
    performance."""
    remaining = list(plans)
    chosen = []
    for keyfn in (lambda p: p["innovation"],
                  lambda p: p["performance"][1],
                  lambda p: p["performance"][0]):
        if not remaining:
            break
        best = max(remaining, key=keyfn)
        chosen.append(best)
        remaining.remove(best)
    return chosen
