"""Subprocess evaluation worker — ``python -m repro.core.eval_worker``.

The child half of :class:`repro.core.transport.SubprocessTransport`.  Reads
length-prefixed JSONL frames on stdin, writes frames on stdout:

1. receives the ``init`` frame, builds its ``EvaluationService`` (or a
   fault-injection wrapper stack) from the JSON *service spec*, replies
   ``hello``;
2. starts a heartbeat thread that emits a ``heartbeat`` frame every
   ``heartbeat_interval_s`` — including while an evaluation is running, so
   the parent can tell "slow benchmark" from "dead process";
3. loops: each ``submit`` frame is evaluated under the (numeric subset of
   the) parent's retry policy and answered with a ``result`` frame, or an
   ``error`` frame when the retries are exhausted;
4. exits on the ``shutdown`` frame or stdin EOF.

Service specs
-------------
A spec is ``{"kind": ..., ...}``; wrapper kinds nest an ``"inner"`` spec.
Producers are the ``service_spec()`` methods on ``EvaluationService`` /
``FlakyService`` / ``CrashService``; :func:`build_service` is the single
consumer.  The *incarnation* (how many times this worker slot has been
respawned) is folded into fault-injection seeds so a deterministic crash
draw cannot kill every respawn at the same call index forever.

Two extra kinds exist for protocol tests and transport diagnostics without
pulling jax into the child: ``echo`` (instant content-keyed verdicts) and
``sleepy`` (stalls on matching sources for incarnation 0 — exercises the
parent's deadline/requeue path).
"""
from __future__ import annotations

import hashlib
import os
import sys
import threading
import time

from . import resilience
from .evaluator import EvalResult
from .transport import read_frame, write_frame


class EchoService:
    """Instant deterministic verdicts keyed on the source content — the
    platform contract (content-pure results) without jax or the cost model.
    For wire-protocol and liveness tests only."""

    def __init__(self, latency_s: float = 0.0) -> None:
        self.latency_s = latency_s
        self.submissions = 0

    def submit(self, source: str) -> EvalResult:
        self.submissions += 1
        if self.latency_s:
            time.sleep(self.latency_s)
        digest = hashlib.sha256(source.encode()).hexdigest()
        return EvalResult("ok", timings_us={
            "len": float(len(source)),
            "sha16": float(int(digest[:4], 16))})

    def clone(self) -> "EchoService":
        return EchoService(latency_s=self.latency_s)

    def service_spec(self) -> dict:
        return {"kind": "echo", "latency_s": self.latency_s}


class SleepyService:
    """Stalls (sleeps ``sleep_s``) on sources containing ``match`` — but
    only at incarnation 0, so the respawned worker makes progress.  Drives
    the parent's stall-deadline detection in tests."""

    def __init__(self, inner, match: str = "STALL", sleep_s: float = 30.0,
                 incarnation: int = 0) -> None:
        self.inner = inner
        self.match = match
        self.sleep_s = sleep_s
        self.incarnation = incarnation

    def submit(self, source: str) -> EvalResult:
        if self.incarnation == 0 and self.match in source:
            time.sleep(self.sleep_s)
        return self.inner.submit(source)

    def clone(self) -> "SleepyService":
        return SleepyService(self.inner.clone(), match=self.match,
                             sleep_s=self.sleep_s,
                             incarnation=self.incarnation)

    def service_spec(self) -> dict:
        return {"kind": "sleepy", "inner": self.inner.service_spec(),
                "match": self.match, "sleep_s": self.sleep_s}

    def __getattr__(self, name):
        return getattr(self.inner, name)


def build_service(spec: dict, incarnation: int = 0):
    """Rebuild a service (stack) from its JSON spec inside the worker."""
    kind = spec.get("kind")
    if kind == "evaluation":
        from .evaluator import EvaluationService
        kwargs = {k: spec[k] for k in
                  ("backend", "noise", "seed", "rtol", "latency_s")
                  if k in spec}
        if "bench_configs" in spec:
            kwargs["bench_configs"] = tuple(
                tuple(c) for c in spec["bench_configs"])
        if "correctness_config" in spec:
            kwargs["correctness_config"] = tuple(spec["correctness_config"])
        return EvaluationService(**kwargs)
    if kind == "flaky":
        from .resilience import FlakyService
        return FlakyService(
            build_service(spec["inner"], incarnation),
            seed=spec.get("seed", 0),
            error_rate=spec.get("error_rate", 0.1),
            timeout_rate=spec.get("timeout_rate", 0.0))
    if kind == "crash":
        from .resilience import CrashService
        return CrashService(
            build_service(spec["inner"], incarnation),
            seed=spec.get("seed", 0),
            crash_rate=spec.get("crash_rate", 0.1),
            incarnation=incarnation)
    if kind == "corrupt_timing":
        from .resilience import CorruptTimingService
        return CorruptTimingService(
            build_service(spec["inner"], incarnation),
            seed=spec.get("seed", 0),
            corrupt_rate=spec.get("corrupt_rate", 0.1),
            factor=spec.get("factor", 5.0))
    if kind == "poison":
        from .resilience import POISON_MARKER, PoisonService
        return PoisonService(
            build_service(spec["inner"], incarnation),
            marker=spec.get("marker", POISON_MARKER))
    if kind == "drift":
        from .resilience import DriftService
        return DriftService(
            build_service(spec["inner"], incarnation),
            drift_after=spec.get("drift_after", 0),
            drift_factor=spec.get("drift_factor", 1.5),
            incarnation=incarnation)
    if kind == "echo":
        return EchoService(latency_s=spec.get("latency_s", 0.0))
    if kind == "sleepy":
        return SleepyService(
            build_service(spec["inner"], incarnation),
            match=spec.get("match", "STALL"),
            sleep_s=spec.get("sleep_s", 30.0),
            incarnation=incarnation)
    raise ValueError(f"unknown service spec kind {kind!r}")


def _policy_from(d) -> resilience.RetryPolicy:
    if not d:
        return resilience.DEFAULT_POLICY
    return resilience.RetryPolicy(
        **{k: v for k, v in d.items()
           if k in ("max_attempts", "base_delay_s", "multiplier",
                    "max_delay_s", "jitter", "timeout_s", "seed")})


def serve(stdin, stdout) -> None:
    """Frame loop over binary streams (factored out for in-process tests)."""
    init = read_frame(stdin)
    if not init or init.get("frame") != "init":
        raise SystemExit("eval_worker: expected an init frame first")
    incarnation = init.get("incarnation", 0)
    service = build_service(init["spec"], incarnation=incarnation)
    policy = _policy_from(init.get("policy"))

    wlock = threading.Lock()

    def send(obj: dict) -> None:
        with wlock:
            write_frame(stdout, obj)

    send({"frame": "hello", "pid": os.getpid(),
          "worker": init.get("worker"), "incarnation": incarnation})

    stop = threading.Event()
    interval = init.get("heartbeat_interval_s", 0.5)

    def beat() -> None:
        while not stop.wait(interval):
            try:
                send({"frame": "heartbeat"})
            except (OSError, ValueError):
                os._exit(0)       # parent went away; nothing left to serve

    threading.Thread(target=beat, daemon=True).start()

    while True:
        frame = read_frame(stdin)
        if frame is None or frame.get("frame") == "shutdown":
            break
        if frame.get("frame") != "submit":
            continue
        job_id = frame.get("job_id")
        try:
            res = resilience.retry_call(
                lambda: service.submit(frame["source"]), policy=policy)
            send({"frame": "result", "job_id": job_id, "status": res.status,
                  "error": res.error, "timings_us": res.timings_us})
        except Exception as e:
            send({"frame": "error", "job_id": job_id,
                  "error": f"{type(e).__name__}: {e}"})
    stop.set()


def main() -> None:
    serve(sys.stdin.buffer, sys.stdout.buffer)


if __name__ == "__main__":
    main()
