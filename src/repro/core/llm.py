"""LLM backends for the three Kernel Scientist stages.

``LLMClient`` is the only seam between the search infrastructure and the
language model (the paper used Gemini 2.5 Pro/Flash; the model is a swappable
commodity).  Two backends:

* ``HTTPChatLLM`` — production: any OpenAI-compatible chat-completions
  endpoint (env: KS_LLM_ENDPOINT / KS_LLM_MODEL / KS_LLM_API_KEY).  Untestable
  in this offline container.
* ``ScriptedLLM`` — a deterministic rule-based oracle that reproduces the
  *decision policies* the paper's appendix shows its LLM making (A.1
  selection rationales, A.2 experiment schema with performance/innovation
  estimates, A.3 writer reports).  It reads only the machine-readable state
  block inside each prompt — i.e. exactly the information a hosted LLM would
  see — and replies in the same JSON schema, so swapping backends changes no
  other code.

The ScriptedLLM's performance estimates use a *deliberately simplified*
napkin model (HBM traffic + peak FLOPs, summed, with an optimistic belief in
split-K).  It is NOT the evaluation platform's cost model: like the paper's
LLM, the designer can be wrong, and refuted hypotheses are part of the
discovery process (paper §4.4).
"""
from __future__ import annotations

import json
import math
import os
import re
import urllib.request

from . import prompts
from .genome import HBM_BW, MXU_BF16_FLOPS, MXU_F32_FLOPS, KernelGenome


class LLMUnavailable(RuntimeError):
    pass


class LLMClient:
    def complete(self, prompt: str) -> str:  # pragma: no cover - interface
        raise NotImplementedError


class HTTPChatLLM(LLMClient):
    """OpenAI-compatible chat endpoint (e.g. a hosted Gemini/Claude proxy)."""

    def __init__(self, endpoint: str | None = None, model: str | None = None,
                 api_key: str | None = None, temperature: float = 0.7,
                 timeout: float = 120.0) -> None:
        self.endpoint = endpoint or os.environ.get("KS_LLM_ENDPOINT")
        self.model = model or os.environ.get("KS_LLM_MODEL", "gemini-2.5-pro")
        self.api_key = api_key or os.environ.get("KS_LLM_API_KEY", "")
        self.temperature = temperature
        self.timeout = timeout

    def complete(self, prompt: str) -> str:
        if not self.endpoint:
            raise LLMUnavailable(
                "no KS_LLM_ENDPOINT configured (offline container?) — "
                "use ScriptedLLM for deterministic offline runs")
        body = json.dumps({
            "model": self.model,
            "temperature": self.temperature,
            "messages": [{"role": "user", "content": prompt}],
        }).encode()
        req = urllib.request.Request(
            self.endpoint, data=body,
            headers={"Content-Type": "application/json",
                     "Authorization": f"Bearer {self.api_key}"})
        with urllib.request.urlopen(req, timeout=self.timeout) as resp:
            payload = json.loads(resp.read())
        return payload["choices"][0]["message"]["content"]


# ---------------------------------------------------------------------------
# ScriptedLLM — the offline oracle
# ---------------------------------------------------------------------------
_CFG_RE = re.compile(r"m(\d+)_n(\d+)_k(\d+)")


def _parse_cfg(key: str) -> tuple:
    m = _CFG_RE.fullmatch(key)
    if not m:
        raise ValueError(f"unparseable benchmark config key {key!r}")
    return tuple(int(g) for g in m.groups())


class ScriptedLLM(LLMClient):
    def __init__(self, seed: int = 0) -> None:
        self.seed = seed
        self._calls = 0

    # ------------------------------------------------- resumable campaigns
    def state_dict(self) -> dict:
        """Jitter state to persist so a resumed campaign replays the same
        decision sequence as an uninterrupted one."""
        return {"calls": self._calls}

    def load_state_dict(self, d: dict) -> None:
        self._calls = d["calls"]

    def _jitter(self, *parts) -> float:
        """Deterministic pseudo-randomness in [-1, 1] — the sampling-
        temperature analogue that keeps repeated designer calls from
        proposing an identical slate every generation."""
        import hashlib
        h = hashlib.sha256(
            ":".join(str(p) for p in (self.seed, self._calls) + parts)
            .encode()).digest()
        return int.from_bytes(h[:8], "big") / 2**63 - 1.0

    # ------------------------------------------------------------------ api
    def complete(self, prompt: str) -> str:
        self._calls += 1
        state = prompts.extract_state(prompt)
        stage = state["stage"]
        if stage == "selector":
            return json.dumps(self._select(state))
        if stage == "designer":
            return json.dumps(self._design(state, prompt))
        if stage == "writer":
            return json.dumps(self._write(state))
        raise ValueError(f"unknown stage {stage!r}")

    # ------------------------------------------------------------- selector
    def _select(self, state: dict) -> dict:
        rows = state["population"]
        ok = [r for r in rows if r["status"] == "ok" and r["score_geomean_us"]]
        if not ok:
            raise ValueError("selector called with no evaluated kernels")
        # The Base must be editable kernel code: the provided library
        # implementation is a benchmark row, not a diffable submission
        # (paper §3: experiments modify the HIP kernel, never PyTorch).
        editable = [r for r in ok if r.get("kind", "kernel") == "kernel"]
        basis = min(editable or ok, key=lambda r: r["score_geomean_us"])

        # per-config champions among the non-basis members
        champions: dict[str, tuple] = {}
        for r in ok:
            for key, t in r["benchmarks_us"].items():
                if t and (key not in champions or t < champions[key][1]):
                    champions[key] = (r["id"], t)

        ancestors = _ancestor_map(rows)

        def divergent(a: str, b: str) -> bool:
            return (b not in ancestors[a] and a not in ancestors[b])

        # Rule i (A.1 samples 1 & 3): a member that uniquely beats the basis
        # on some configuration, preferring a divergent lineage.
        uniquely_strong = []
        for key, (rid, t) in champions.items():
            if rid != basis["id"]:
                uniquely_strong.append((rid, key, t))
        reference = rationale = None
        if uniquely_strong:
            div = [u for u in uniquely_strong if divergent(u[0], basis["id"])]
            pick = sorted(div or uniquely_strong)[0]
            reference = pick[0]
            mnk = pick[1]
            flavour = ("represents a divergent optimization path from a common "
                       "ancestor" if div else "is an ancestor with a higher "
                       "total benchmark score")
            rationale = (
                f"Run {basis['id']} is selected as the basis code due to its "
                f"consistently lowest geometric-mean benchmark score across all "
                f"input configurations. Run {reference} is chosen as the "
                f"reference because it {flavour}, and it uniquely performs "
                f"better on one specific configuration ({mnk}), providing "
                f"valuable insight into optimization trade-offs for the kernel "
                f"scientist.")
        else:
            # Rule ii (A.1 sample 2): fall back to the direct parent.
            parent = basis["parents"][0] if basis["parents"] else None
            others = [r["id"] for r in ok if r["id"] != basis["id"]]
            reference = parent if parent else (sorted(others)[0] if others
                                               else basis["id"])
            rationale = (
                f"Run {basis['id']} is selected as the basis code due to its "
                f"superior overall performance. Run {reference}, its direct "
                f"parent, is chosen as the reference because it represents the "
                f"immediate previous highly optimized iteration, providing "
                f"crucial context for understanding the precise improvements "
                f"leading to the current best performance.")
        return {"basis_code": basis["id"], "basis_reference": reference,
                "rationale": rationale}

    # ------------------------------------------------------------- designer
    def _napkin_us(self, genome: dict, m: int, n: int, k: int) -> float:
        """The designer's own (simplified, fallible) cost estimate."""
        if genome.get("style") == "library":
            return (2 * m * n * k / (0.7 * MXU_BF16_FLOPS)
                    + 3 * (m * k + k * n) / HBM_BW) * 1e6
        bm = min(genome["block_m"], _ceil(m, 128))
        bn = min(genome["block_n"], _ceil(n, 128))
        bk = min(genome["block_k"], _ceil(k, 128))
        mp, np_, kp = _ceil(m, bm), _ceil(n, bn), _ceil(k, bk)
        gm, gn = mp // bm, np_ // bn
        ks = genome.get("k_split", 1)
        traffic = mp * kp * gn + kp * np_ * gm + 2 * mp * np_
        if ks > 1:
            traffic += 8 * mp * np_ * ks
        rate = (MXU_BF16_FLOPS if genome.get("compute_dtype") == "bfloat16"
                else MXU_F32_FLOPS)
        compute = 2 * mp * np_ * kp / rate
        if ks > 1 and gm * gn < 16:
            compute *= 0.7  # optimistic occupancy belief (can be refuted)
        return (traffic / HBM_BW + compute) * 1e6  # sum, not max: simplified

    def _design(self, state: dict, prompt: str) -> dict:
        base = state["base"]
        base_genome = json.loads(base["genome"]) if base.get("genome") else None
        cfgs = [_parse_cfg(key) for key in base.get("benchmarks", {})]
        if not cfgs:
            cfgs = [(1024, 1536, 7168), (6144, 7168, 2048), (6144, 4096, 512)]

        plans = []
        for cand in state["candidate_edits"]:
            edit = cand["genome_edit"]
            if base_genome is not None:
                new_genome = dict(base_genome, **edit)
            else:
                new_genome = json.loads(KernelGenome().to_json())
                new_genome.update(edit)
            gains = []
            for (m, n, k) in cfgs:
                t0 = self._napkin_us(base_genome or {"style": "library"}, m, n, k)
                t1 = self._napkin_us(new_genome, m, n, k)
                gains.append((t0 - t1) / t0 * 100.0)
            gain = sum(gains) / len(gains)
            lo = max(-30, int(math.floor(0.4 * gain - 2)))
            hi = min(90, int(math.ceil(1.2 * gain + 6)))
            hi = max(hi, lo + 1)
            categorical = any(not isinstance(v, int) for v in edit.values())
            innov = min(100, cand["innovation_prior"] + (10 if categorical else 0))
            plans.append({
                "description": f"[{cand['avenue']}] {cand['rubric'].splitlines()[0]}",
                "rubric": cand["rubric"],
                "performance": [lo, hi],
                "innovation": innov,
                "genome_edit": edit,
                "_napkin_gain": round(gain, 2),
            })

        # 5 plans, avenue-diverse, ranked by predicted upper bound with an
        # exploration jitter so successive designer calls vary the slate
        plans.sort(key=lambda p: (-(p["performance"][1]
                                    + 4.0 * self._jitter(p["description"])),
                                  p["description"]))
        chosen: list[dict] = []
        seen_avenues: dict[str, int] = {}
        for p in plans:
            avenue = p["description"].split("]")[0][1:]
            if seen_avenues.get(avenue, 0) >= 2:
                continue
            chosen.append(p)
            seen_avenues[avenue] = seen_avenues.get(avenue, 0) + 1
            if len(chosen) == 5:
                break
        for p in plans:  # backfill if diversity filter left fewer than 5
            if len(chosen) == 5:
                break
            if p not in chosen:
                chosen.append(p)

        avenues = _extract_avenue_texts(prompt)
        return {"avenues": avenues[:10], "experiments": chosen}

    # --------------------------------------------------------------- writer
    def _write(self, state: dict) -> dict:
        from . import codegen  # local import: keep module import-light

        exp = state["experiment"]
        base = state["base"]
        edit = exp.get("genome_edit")
        if base.get("genome") is None and not edit:
            return {"source": base["source"], "genome": None,
                    "report": "Declined: the rubric requires structural source "
                              "edits outside the documented design space; "
                              "resubmitting the base unchanged."}
        base_genome = (KernelGenome.from_json(base["genome"])
                       if base.get("genome") else KernelGenome())
        genome = base_genome
        deviations = []
        if edit:
            clean = dict(edit)
            if "dimension_semantics" in clean:
                clean["dimension_semantics"] = tuple(clean["dimension_semantics"])
            genome = base_genome.replace(**clean)
        # deterministic repair loop — mirrors the paper's observation that the
        # writer sometimes implements *part* of a rubric and reports it
        for _ in range(10):
            errs = genome.validate()
            if not errs:
                break
            if genome.vmem_bytes() > 0 and "VMEM" in " ".join(errs):
                big = max(("block_m", "block_n", "block_k"),
                          key=lambda a: getattr(genome, a))
                genome = genome.replace(**{big: getattr(genome, big) // 2})
                deviations.append(
                    f"halved {big} to keep the VMEM working set legal")
            else:
                genome = base_genome
                deviations.append("rubric produced an illegal configuration; "
                                  "reverted to the base genome")
                break
        source = codegen.render_source(genome, exp["description"])
        changed = _diff_fields(base_genome, genome)
        report = ("Implemented: " + (", ".join(changed) if changed
                                     else "no effective change") + ".")
        if deviations:
            report += " Deviations from rubric: " + "; ".join(deviations) + "."
        return {"source": source,
                "genome": json.loads(genome.to_json()),
                "report": report}


# ------------------------------------------------------------------ helpers
def _ceil(x: int, m: int) -> int:
    return -(-x // m) * m


def _ancestor_map(rows: list) -> dict:
    parents = {r["id"]: list(r.get("parents", [])) for r in rows}
    out: dict[str, set] = {}
    for rid in parents:
        seen: set[str] = set()
        stack = list(parents.get(rid, []))
        while stack:
            p = stack.pop()
            if p not in seen:
                seen.add(p)
                stack.extend(parents.get(p, []))
        out[rid] = seen
    return out


def _diff_fields(a: KernelGenome, b: KernelGenome) -> list:
    out = []
    for f in ("style", "block_m", "block_n", "block_k", "grid_order",
              "scale_application", "compute_dtype", "k_split",
              "dimension_semantics"):
        va, vb = getattr(a, f), getattr(b, f)
        if va != vb:
            out.append(f"{f}: {va} -> {vb}")
    return out


def _extract_avenue_texts(prompt: str) -> list:
    lines = []
    in_section = False
    for line in prompt.splitlines():
        if line.startswith("## Avenue starting points"):
            in_section = True
            continue
        if in_section:
            if line.startswith("## "):
                break
            if line.startswith("- "):
                lines.append(line[2:])
    return lines
