"""Prompt builders for the three LLM stages (paper §3.1-3.3).

Each prompt is real natural-language text with the same informational content
the paper describes, plus a fenced machine-readable JSON state block.  A
hosted LLM reads the whole prompt; the offline ScriptedLLM oracle reads only
the state block.  Both reply with a single fenced JSON object, so the stage
parsers are backend-agnostic.
"""
from __future__ import annotations

import json
from typing import Optional

STATE_OPEN = "<<<STATE_JSON"
STATE_CLOSE = "STATE_JSON>>>"


def _state_block(payload: dict) -> str:
    return f"{STATE_OPEN}\n{json.dumps(payload, indent=1)}\n{STATE_CLOSE}"


def extract_state(prompt: str) -> dict:
    start = prompt.index(STATE_OPEN) + len(STATE_OPEN)
    end = prompt.index(STATE_CLOSE)
    return json.loads(prompt[start:end])


def extract_reply_json(reply: str) -> dict:
    """Parse the model's reply: first try the whole string, then the outermost
    fenced/brace-delimited JSON object (robust to prose around it)."""
    reply = reply.strip()
    try:
        return json.loads(reply)
    except json.JSONDecodeError:
        pass
    start = reply.index("{")
    depth = 0
    for i in range(start, len(reply)):
        if reply[i] == "{":
            depth += 1
        elif reply[i] == "}":
            depth -= 1
            if depth == 0:
                return json.loads(reply[start:i + 1])
    raise ValueError("no JSON object in LLM reply")


# ---------------------------------------------------------------- selector
def selector_prompt(summary_rows: list, task_text: str) -> str:
    payload = {"stage": "selector", "population": summary_rows}
    return f"""You are the Evolutionary Selector of a GPU Kernel Scientist
system optimizing one accelerator kernel through iterative experiments.

## Task under optimization
{task_text}

## Population
Each member below is a kernel version: its ID, its parents' IDs, and its
benchmark timings in microseconds over the specified MxKxN input
configurations (lower is better; the leaderboard metric is the geometric
mean).  Failed members show their platform feedback instead of timings.

{_state_block(payload)}

## Instructions
Choose exactly one member as the 'Base' for the next experiment (the code
that will be modified) and one other member as the 'Reference' (chosen for
its ability to help in analysing experiments: e.g. a divergent optimization
path, or a member uniquely strong on one configuration).  Reply with a single
JSON object: {{"basis_code": "<id>", "basis_reference": "<id>",
"rationale": "<2-4 sentences>"}}"""


# ---------------------------------------------------------------- designer
def designer_prompt(base_analysis: dict, reference_analysis: dict,
                    base_source: str, findings: str, avenue_texts: list,
                    candidate_edits: list, task_text: str,
                    quarantined: Optional[list] = None) -> str:
    payload = {
        "stage": "designer",
        "base": base_analysis,
        "reference": reference_analysis,
        "candidate_edits": candidate_edits,
    }
    quarantine_section = ""
    if quarantined:
        payload["quarantined"] = quarantined
        quarantine_section = (
            "\n## Quarantined kernels (do not redesign these)\n"
            "The kernels listed under 'quarantined' in the state block "
            "crashed or wedged evaluation workers repeatedly and are "
            "blacklisted: any plan producing an equivalent kernel will be "
            "rejected without measurement.  Steer your experiment plans "
            "away from those configurations.\n")
    avenues = "\n".join(f"- {t}" for t in avenue_texts)
    return f"""You are the Experiment Designer of a GPU Kernel Scientist
system.  Design the next round of optimization experiments for the kernel
below, using only black-box timing feedback.

## Task under optimization
{task_text}

## Findings document (assimilated hardware knowledge)
{findings}

## Base kernel source
```python
{base_source}
```

## One-step experiment analyses (base, then reference)
{_state_block(payload)}

## Avenue starting points
{avenues}
{quarantine_section}
## Instructions
First produce 10 optimization 'avenues' that might be considered (a longer
list than needed, to increase diversity).  Then produce exactly 5 experiment
plans.  Each plan must have: a description; a multi-line rubric precise
enough for a kernel writer to implement; your estimate of the performance
benefit range in percent as [lo, hi]; and an 'innovation' score 0-100 for
how structurally novel the experiment is.  Where a plan corresponds to one
of the machine-readable candidate_edits in the state block, copy its
'genome_edit' field into the plan.  Reply with a single JSON object:
{{"avenues": [...10 strings...], "experiments": [{{"description": str,
"rubric": str, "performance": [lo, hi], "innovation": int,
"genome_edit": {{...}} | null}}, ... 5 plans ...]}}"""


# ------------------------------------------------------------------ writer
def writer_prompt(experiment: dict, base_record: dict, reference_record: dict,
                  findings: str, task_text: str) -> str:
    payload = {
        "stage": "writer",
        "experiment": experiment,
        "base": base_record,
        "reference": reference_record,
    }
    return f"""You are the Kernel Writer of a GPU Kernel Scientist system.
Implement the experiment below as a modification ('diff') of the Base kernel.
The Reference kernel is provided for contrast only.

## Task under optimization
{task_text}

## Findings document
{findings}

## Experiment to implement
Description: {experiment['description']}
Rubric:
{experiment['rubric']}

## Base kernel (modify this one)
```python
{base_record['source']}
```

## Reference kernel (context only)
```python
{reference_record['source']}
```

## One-step experiment analyses
{_state_block(payload)}

## Instructions
Produce the complete new kernel module (it must define
`run(a, b, a_scale, b_scale)` and a `GENOME` json string describing its
configuration) plus a short report of which techniques you actually used —
note explicitly if you deviated from the rubric and why.  Reply with a
single JSON object: {{"source": "<python module text>",
"genome": {{...}}, "report": "<what was implemented>"}}"""


TASK_TEXT = """Block-scaled FP8 GEMM (AMD Developer Challenge 2025 task,
re-targeted to TPU v5e): C[bf16][M,N] = dequant(A[fp8_e4m3][M,K]) @
dequant(B[fp8_e4m3][K,N]) where a_scale is f32 per (row, 128-K-block) and
b_scale is f32 per (128x128)-block; accumulation in f32.  The evaluation
platform compiles the submitted Pallas source, verifies numerical
correctness against a reference oracle, and returns end-to-end execution
time per benchmark configuration — no profiler output is available, and
submissions run sequentially."""
