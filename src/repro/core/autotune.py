"""Beyond-paper: the Kernel Scientist's black-box loop applied to the
FRAMEWORK itself.

The paper optimizes one kernel against an opaque timing platform.  The same
structure transfers one level up: a *framework genome* (attention tile
sizes, loss chunking, gradient-accumulation factor) is evaluated by
lowering the full distributed step and reading the roofline bound from the
compiled artifact — compile-and-analyse as the black-box 'timing' signal.
The loop is the paper's: propose experiments from the current best, submit
sequentially, keep lineage + refutation logs.

    PYTHONPATH=src python -m repro.core.autotune --arch qwen1.5-110b \\
        --shape train_4k --budget 8
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import sys
from typing import Optional

import jax

from repro.roofline.report import (
    HBM_BW, ICI_LINK_BW, PEAK_FLOPS, flat_cost_analysis)


@dataclasses.dataclass(frozen=True)
class FrameworkGenome:
    attn_q_chunk: int = 512
    attn_k_chunk: int = 1024
    loss_chunk: int = 8192
    microbatches: int = 1

    def neighbours(self):
        out = []
        for field, opts in (
            ("attn_q_chunk", (256, 512, 1024, 2048)),
            ("attn_k_chunk", (256, 512, 1024, 2048)),
            ("loss_chunk", (4096, 8192, 16384, 32768)),
            ("microbatches", (1, 2, 4, 8, 16)),
        ):
            cur = getattr(self, field)
            idx = opts.index(cur) if cur in opts else 1
            for j in (idx - 1, idx + 1):
                if 0 <= j < len(opts) and opts[j] != cur:
                    out.append((f"{field}: {cur} -> {opts[j]}",
                                dataclasses.replace(self,
                                                    **{field: opts[j]})))
        return out


class CellEvaluationService:
    """Sequential black-box evaluation: lower+compile one framework genome
    for an (arch x shape) cell; the score is the dominant roofline term."""

    def __init__(self, arch_id: str, shape_name: str, mesh=None):
        from repro import configs
        from repro.launch.mesh import make_production_mesh
        from repro.models import SHAPES
        self.cfg0 = configs.get_config(arch_id)
        self.shape = SHAPES[shape_name]
        self.mesh = mesh if mesh is not None else make_production_mesh()
        self.submissions = 0

    def submit(self, genome: FrameworkGenome) -> dict:
        from repro.dist import partition
        from repro.launch import dryrun
        from repro.roofline.collectives import collective_bytes_from_hlo
        self.submissions += 1
        cfg = dataclasses.replace(
            self.cfg0, attn_q_chunk=genome.attn_q_chunk,
            attn_k_chunk=genome.attn_k_chunk, loss_chunk=genome.loss_chunk)
        dryrun.TRAIN_MICROBATCHES = dict(dryrun.TRAIN_MICROBATCHES,
                                         **{cfg.name: genome.microbatches})
        partition.set_mesh(self.mesh)
        try:
            with self.mesh:
                fn, args, sh, osh, dn = dryrun.build_cell(cfg, self.shape,
                                                          self.mesh)
                compiled = jax.jit(
                    fn, in_shardings=sh, out_shardings=osh,
                    donate_argnums=dn).lower(*args).compile()
                cost = flat_cost_analysis(compiled)
                mem = compiled.memory_analysis()
                coll = collective_bytes_from_hlo(compiled.as_text())
        except Exception as e:
            return {"status": "compile_error", "error": str(e)[:400]}
        finally:
            partition.set_mesh(None)
        terms = {
            "compute": cost.get("flops", 0.0) / PEAK_FLOPS,
            "memory": cost.get("bytes accessed", 0.0) / HBM_BW,
            "collective": coll / ICI_LINK_BW,
        }
        hbm = (mem.argument_size_in_bytes + mem.temp_size_in_bytes) / 2**30
        return {"status": "ok", "terms": terms,
                "bound_s": max(terms.values()),
                "dominant": max(terms, key=terms.get),
                "hbm_gib": hbm, "fits": hbm <= 16.0}


def autotune_cell(arch_id: str, shape_name: str, budget: int = 8,
                  mesh=None, start: Optional[FrameworkGenome] = None,
                  verbose: bool = True) -> dict:
    """Greedy neighbourhood hillclimb with a hypothesis->measure log.
    Over-budget genomes are rejected regardless of speed (fit is a hard
    constraint, exactly like the platform's VMEM compile errors)."""
    svc = CellEvaluationService(arch_id, shape_name, mesh)
    cur = start or FrameworkGenome()
    cur_res = svc.submit(cur)
    log = [{"genome": dataclasses.asdict(cur), "result": cur_res,
            "note": "baseline"}]
    if verbose:
        print(f"baseline: {cur_res}")
    tried = {cur}
    while svc.submissions < budget:
        candidates = [c for c in cur.neighbours() if c[1] not in tried]
        if not candidates:
            break
        progressed = False
        for note, cand in candidates:
            if svc.submissions >= budget:
                break
            tried.add(cand)
            res = svc.submit(cand)
            ok = (res["status"] == "ok" and res["fits"]
                  and res["bound_s"] < cur_res.get("bound_s", 1e30))
            log.append({"genome": dataclasses.asdict(cand), "result": res,
                        "note": note,
                        "verdict": "accepted" if ok else "rejected"})
            if verbose:
                b = res.get("bound_s")
                print(f"{note}: bound={b if b is None else round(b, 4)} "
                      f"fits={res.get('fits')} -> "
                      f"{'ACCEPT' if ok else 'reject'}")
            if ok:
                cur, cur_res = cand, res
                progressed = True
                break
        if not progressed:
            break
    return {"best_genome": dataclasses.asdict(cur), "best": cur_res,
            "log": log, "submissions": svc.submissions}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--budget", type=int, default=8)
    ap.add_argument("--out", default=None)
    args = ap.parse_args(argv)
    result = autotune_cell(args.arch, args.shape, args.budget)
    if args.out:
        import pathlib
        pathlib.Path(args.out).write_text(json.dumps(result, indent=1))
    print(json.dumps(result["best"], indent=1))
    return 0


if __name__ == "__main__":
    sys.exit(main())
