"""Pure-jnp reference oracles for every Pallas kernel in this package.

These are the ground truth used by tests/allclose sweeps and by the
EvaluationService's correctness check (the competition platform's "verified
to give correct results" role, paper §3).  They are deliberately simple and
written for clarity, not speed.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

SCALE_BLOCK = 128  # quantization block edge (matches the AMD challenge spec)


# ---------------------------------------------------------------------------
# Block-scaled GEMM (the paper's target kernel)
# ---------------------------------------------------------------------------
def scaled_gemm(a, b, a_scale, b_scale, out_dtype=jnp.bfloat16):
    """C = dequant(A) @ dequant(B), fp32 accumulate.

    a        : (M, K)       storage dtype (float8_e4m3fn / int8 / bf16)
    b        : (K, N)       same storage dtype
    a_scale  : (M, K/128)   f32 — per-row, per-128-K-block scales
    b_scale  : (K/128, N/128) f32 — per-128x128-block scales
    returns  : (M, N) out_dtype
    """
    m, k = a.shape
    k2, n = b.shape
    assert k == k2
    kb = k // SCALE_BLOCK
    a32 = a.astype(jnp.float32).reshape(m, kb, SCALE_BLOCK)
    a32 = a32 * a_scale.astype(jnp.float32)[:, :, None]
    b32 = b.astype(jnp.float32).reshape(kb, SCALE_BLOCK, n // SCALE_BLOCK, SCALE_BLOCK)
    b32 = b32 * b_scale.astype(jnp.float32)[:, None, :, None]
    out = jnp.einsum(
        "mks,kstu->mtu",
        a32,
        b32,
        precision=jax.lax.Precision.HIGHEST,
    ).reshape(m, n)
    return out.astype(out_dtype)


def quantize_blockwise(x, dtype=jnp.float8_e4m3fn):
    """Quantize a (M, K) f32 matrix into (values, scales) with the layout above.

    For the B operand pass x of shape (K, N) transposed handling is done by
    the caller (see tests) — this helper quantizes per (row, 128-K-block).
    """
    m, k = x.shape
    kb = k // SCALE_BLOCK
    xr = x.reshape(m, kb, SCALE_BLOCK)
    max_abs = jnp.max(jnp.abs(xr), axis=-1)
    fmax = jnp.array(
        448.0 if dtype == jnp.float8_e4m3fn else (127.0 if dtype == jnp.int8 else 3e38),
        jnp.float32,
    )
    scale = jnp.where(max_abs > 0, max_abs / fmax, 1.0)
    q = (xr / scale[:, :, None]).astype(dtype)
    return q.reshape(m, k), scale


def quantize_blockwise_2d(x, dtype=jnp.float8_e4m3fn):
    """Quantize (K, N) into values + (K/128, N/128) per-block scales."""
    k, n = x.shape
    kb, nb = k // SCALE_BLOCK, n // SCALE_BLOCK
    xr = x.reshape(kb, SCALE_BLOCK, nb, SCALE_BLOCK)
    max_abs = jnp.max(jnp.abs(xr), axis=(1, 3))
    fmax = jnp.array(
        448.0 if dtype == jnp.float8_e4m3fn else (127.0 if dtype == jnp.int8 else 3e38),
        jnp.float32,
    )
    scale = jnp.where(max_abs > 0, max_abs / fmax, 1.0)
    q = (xr / scale[:, None, :, None]).astype(dtype)
    return q.reshape(k, n), scale


# ---------------------------------------------------------------------------
# Flash attention (prefill) — plain softmax attention oracle
# ---------------------------------------------------------------------------
def attention(q, k, v, *, causal=True, window=None, scale=None):
    """q: (B, Hq, S, D), k/v: (B, Hkv, S, D) with Hq % Hkv == 0 (GQA).

    window: if not None, token i attends to [i-window+1, i] only (local attn).
    """
    b, hq, s, d = q.shape
    hkv = k.shape[1]
    g = hq // hkv
    scale = scale if scale is not None else 1.0 / (d**0.5)
    qf = q.astype(jnp.float32).reshape(b, hkv, g, s, d)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    logits = jnp.einsum("bhgsd,bhtd->bhgst", qf, kf) * scale
    pos_q = jnp.arange(s)[:, None]
    pos_k = jnp.arange(s)[None, :]
    mask = jnp.ones((s, s), bool)
    if causal:
        mask &= pos_k <= pos_q
    if window is not None:
        mask &= pos_k > pos_q - window
    logits = jnp.where(mask, logits, -jnp.inf)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgst,bhtd->bhgsd", probs, vf)
    return out.reshape(b, hq, s, d).astype(q.dtype)


# ---------------------------------------------------------------------------
# Decode attention (single new token vs a long KV cache)
# ---------------------------------------------------------------------------
def decode_attention(q, k, v, kv_len, *, scale=None):
    """q: (B, Hq, D); k/v: (B, Hkv, S, D); kv_len: (B,) valid prefix lengths."""
    b, hq, d = q.shape
    hkv, s = k.shape[1], k.shape[2]
    g = hq // hkv
    scale = scale if scale is not None else 1.0 / (d**0.5)
    qf = q.astype(jnp.float32).reshape(b, hkv, g, d)
    logits = jnp.einsum("bhgd,bhtd->bhgt", qf, k.astype(jnp.float32)) * scale
    valid = jnp.arange(s)[None, :] < kv_len[:, None]  # (B, S)
    logits = jnp.where(valid[:, None, None, :], logits, -jnp.inf)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgt,bhtd->bhgd", probs, v.astype(jnp.float32))
    return out.reshape(b, hq, d).astype(q.dtype)


# ---------------------------------------------------------------------------
# Mamba-2 SSD (state-space duality) — sequential-scan oracle
# ---------------------------------------------------------------------------
def ssd(x, dt, a, b, c, *, d_skip=None):
    """Sequential (exact) SSM scan.

    x : (B, S, H, P)   inputs per head
    dt: (B, S, H)      softplus'd timestep (positive)
    a : (H,)           negative decay rate per head (A = -exp(a_log))
    b : (B, S, N)      input projection (ngroups=1, broadcast over heads)
    c : (B, S, N)      output projection
    d_skip: (H,) or None  skip connection
    returns y: (B, S, H, P)
    """
    bsz, s, h, p = x.shape
    n = b.shape[-1]
    decay = jnp.exp(dt * a[None, None, :])  # (B, S, H)  in (0, 1)

    def step(state, inp):
        x_t, dt_t, dec_t, b_t, c_t = inp
        # state: (B, H, N, P)
        dbx = jnp.einsum("bn,bhp->bhnp", b_t, x_t * dt_t[..., None])
        state = state * dec_t[:, :, None, None] + dbx
        y_t = jnp.einsum("bn,bhnp->bhp", c_t, state)
        return state, y_t

    state0 = jnp.zeros((bsz, h, n, p), jnp.float32)
    xs = (
        jnp.moveaxis(x.astype(jnp.float32), 1, 0),
        jnp.moveaxis(dt.astype(jnp.float32), 1, 0),
        jnp.moveaxis(decay.astype(jnp.float32), 1, 0),
        jnp.moveaxis(b.astype(jnp.float32), 1, 0),
        jnp.moveaxis(c.astype(jnp.float32), 1, 0),
    )
    _, ys = jax.lax.scan(step, state0, xs)
    y = jnp.moveaxis(ys, 0, 1)  # (B, S, H, P)
    if d_skip is not None:
        y = y + x.astype(jnp.float32) * d_skip[None, None, :, None]
    return y.astype(x.dtype)
