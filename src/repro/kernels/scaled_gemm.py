"""Block-scaled GEMM Pallas kernel — the paper's target kernel, TPU-native.

The AMD Developer Challenge task the paper optimizes is
``C[bf16] = dequant(A[fp8]) @ dequant(B[fp8])`` with per-(1x128) scales for A
and per-(128x128) scales for B, fp32 accumulation.  On MI300 the paper's
LLM-evolved kernel used MFMA Matrix Cores + LDS ping-pong double buffering.
The TPU-native equivalent implemented here:

  MI300 MFMA 32x32x16 fragments  ->  MXU jnp.dot, preferred_element_type=f32
  LDS tiles + ping/pong          ->  BlockSpec VMEM tiles + pipelined grid
  LDS scale-caching              ->  scale tiles as extra VMEM block operands
  wave-cooperative stores        ->  grid-owned output tiles

Every axis the paper's Experiment Designer mutated (tile sizes, layouts,
vectorisation, scale application point, write-back) is a keyword parameter
here, so the Kernel Scientist's genome maps 1:1 onto ``pallas_call``
configurations.  See ``repro.core.genome``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax < 0.5 calls it TPUCompilerParams; newer releases renamed it.
_CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams

SCALE_BLOCK = 128


def _kernel_body(
    a_ref,
    b_ref,
    as_ref,
    bs_ref,
    o_ref,
    acc_ref,
    *,
    k_steps: int,
    n_sub: int,
    scale_application: str,
    compute_dtype,
    acc_dtype,
):
    """One (block_m, block_n) output tile, one block_k slab of the K loop."""
    k_idx = pl.program_id(2)

    @pl.when(k_idx == 0)
    def _zero_acc():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    a = a_ref[...]  # (bm, bk) storage dtype
    b = b_ref[...]  # (bk, bn)
    a_s = as_ref[...].astype(jnp.float32)  # (bm, n_sub)
    b_s = bs_ref[...].astype(jnp.float32)  # (n_sub, bn // 128)

    acc = acc_ref[...]
    for s in range(n_sub):  # statically unrolled over 128-wide K sub-blocks
        a_blk = a[:, s * SCALE_BLOCK : (s + 1) * SCALE_BLOCK].astype(jnp.float32)
        b_blk = b[s * SCALE_BLOCK : (s + 1) * SCALE_BLOCK, :].astype(jnp.float32)
        # expand b scales from per-(128x128)-block to per-column
        b_s_cols = jnp.repeat(b_s[s], SCALE_BLOCK)[None, :]  # (1, bn)
        if scale_application == "dequant_inputs":
            # scale before the dot: more VPU work, inputs leave exact bf16 grid
            a_blk = (a_blk * a_s[:, s : s + 1]).astype(compute_dtype)
            b_blk = (b_blk * b_s_cols).astype(compute_dtype)
            part = jnp.dot(a_blk, b_blk, preferred_element_type=acc_dtype)
            acc = acc + part
        else:  # "scale_acc": dot raw quantized values (exact in bf16), scale after
            part = jnp.dot(
                a_blk.astype(compute_dtype),
                b_blk.astype(compute_dtype),
                preferred_element_type=acc_dtype,
            )
            acc = acc + part * a_s[:, s : s + 1] * b_s_cols
    acc_ref[...] = acc

    @pl.when(k_idx == k_steps - 1)
    def _store():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def scaled_gemm(
    a,
    b,
    a_scale,
    b_scale,
    *,
    block_m: int = 256,
    block_n: int = 256,
    block_k: int = 256,
    grid_order: str = "mn",  # which output axis is outermost: "mn" or "nm"
    scale_application: str = "scale_acc",  # or "dequant_inputs"
    compute_dtype=jnp.bfloat16,  # MXU input dtype (bf16) or f32 (slow path)
    acc_dtype=jnp.float32,
    out_dtype=jnp.bfloat16,
    dimension_semantics=("parallel", "parallel", "arbitrary"),
    interpret: bool = True,  # CPU container default; False on real TPU
):
    """Blocked, scale-fused GEMM.  See module docstring for layout contract.

    a: (M, K) storage dtype; b: (K, N); a_scale: (M, K/128) f32;
    b_scale: (K/128, N/128) f32.  M, N, K must divide by the block sizes and
    block_k by 128 (the quantization block): the public wrapper in ``ops.py``
    pads arbitrary shapes first.
    """
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, (a.shape, b.shape)
    assert m % block_m == 0 and n % block_n == 0 and k % block_k == 0, (
        (m, n, k),
        (block_m, block_n, block_k),
    )
    assert block_k % SCALE_BLOCK == 0 and block_n % SCALE_BLOCK == 0
    n_sub = block_k // SCALE_BLOCK
    gm, gn, gk = m // block_m, n // block_n, k // block_k

    body = functools.partial(
        _kernel_body,
        k_steps=gk,
        n_sub=n_sub,
        scale_application=scale_application,
        compute_dtype=compute_dtype,
        acc_dtype=acc_dtype,
    )

    if grid_order == "mn":
        grid = (gm, gn, gk)
        imap_a = lambda i, j, kk: (i, kk)
        imap_b = lambda i, j, kk: (kk, j)
        imap_o = lambda i, j, kk: (i, j)
        imap_as = lambda i, j, kk: (i, kk)
        imap_bs = lambda i, j, kk: (kk, j)
    else:  # "nm": N outermost — trades A-reload traffic for B-reload traffic
        grid = (gn, gm, gk)
        imap_a = lambda j, i, kk: (i, kk)
        imap_b = lambda j, i, kk: (kk, j)
        imap_o = lambda j, i, kk: (i, j)
        imap_as = lambda j, i, kk: (i, kk)
        imap_bs = lambda j, i, kk: (kk, j)

    return pl.pallas_call(
        body,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, block_k), imap_a),
            pl.BlockSpec((block_k, block_n), imap_b),
            pl.BlockSpec((block_m, n_sub), imap_as),
            pl.BlockSpec((n_sub, block_n // SCALE_BLOCK), imap_bs),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), imap_o),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        scratch_shapes=[pltpu.VMEM((block_m, block_n), acc_dtype)],
        compiler_params=_CompilerParams(dimension_semantics=dimension_semantics),
        interpret=interpret,
    )(a, b, a_scale, b_scale)


def naive_scaled_gemm(a, b, a_scale, b_scale, *, out_dtype=jnp.bfloat16, interpret=True):
    """The 'naive HIP translation' seed (paper §3): single grid step, whole
    problem resident, full dequant then one dot.  ~6x slower than the library
    path on MI300; on TPU it simply blows VMEM for real sizes — the cost model
    penalises it the same way."""
    m, k = a.shape
    _, n = b.shape
    n_sub = k // SCALE_BLOCK

    def body(a_ref, b_ref, as_ref, bs_ref, o_ref):
        a32 = a_ref[...].astype(jnp.float32).reshape(m, n_sub, SCALE_BLOCK)
        a32 = a32 * as_ref[...].astype(jnp.float32)[:, :, None]
        b32 = b_ref[...].astype(jnp.float32).reshape(n_sub, SCALE_BLOCK, n)
        bs = bs_ref[...].astype(jnp.float32)  # (n_sub, n//128)
        b32 = b32 * jnp.repeat(bs, SCALE_BLOCK, axis=1)[:, None, :]
        out = jnp.dot(
            a32.reshape(m, k), b32.reshape(k, n), preferred_element_type=jnp.float32
        )
        o_ref[...] = out.astype(o_ref.dtype)

    return pl.pallas_call(
        body,
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        interpret=interpret,
    )(a, b, a_scale, b_scale)
