"""Pallas TPU kernels for the perf-critical hot spots, with pure-jnp oracles.

Layout per the repo convention: ``<name>.py`` holds the ``pl.pallas_call`` +
BlockSpec implementation, ``ops.py`` the jit'd public wrappers, ``ref.py``
the oracles.  ``scaled_gemm`` is the paper's target kernel (the AMD
challenge fp8 block-scaled GEMM, adapted to the TPU memory hierarchy).
"""
from . import ops, ref  # noqa: F401
from .flash_attention import decode_attention, flash_attention  # noqa: F401
from .scaled_gemm import naive_scaled_gemm, scaled_gemm  # noqa: F401
from .ssd import ssd  # noqa: F401
