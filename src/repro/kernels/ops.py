"""Public jit'd wrappers around the Pallas kernels.

These are what the model code and the Kernel Scientist's EvaluationService
call.  Each wrapper handles padding/reshaping to kernel-legal shapes and
dispatches to the pure-jnp reference when ``use_pallas=False`` (the default
for XLA-only paths like the multi-pod dry-run, where kernels are swapped in
on real TPU hardware only).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import flash_attention as _fa
from . import ref as _ref
from . import scaled_gemm as _sg
from . import ssd as _ssd


def _pad_to(x, multiple, axis):
    size = x.shape[axis]
    pad = (-size) % multiple
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


@functools.partial(
    jax.jit,
    static_argnames=(
        "block_m",
        "block_n",
        "block_k",
        "grid_order",
        "scale_application",
        "use_pallas",
        "interpret",
    ),
)
def scaled_gemm(
    a,
    b,
    a_scale,
    b_scale,
    *,
    block_m=256,
    block_n=256,
    block_k=256,
    grid_order="mn",
    scale_application="scale_acc",
    use_pallas=True,
    interpret=True,
):
    if not use_pallas:
        return _ref.scaled_gemm(a, b, a_scale, b_scale)
    m, k = a.shape
    n = b.shape[1]
    block_m = min(block_m, max(128, m))
    block_n = min(block_n, max(128, n))
    block_k = min(block_k, max(128, k))
    ap = _pad_to(_pad_to(a, block_m, 0), block_k, 1)
    bp = _pad_to(_pad_to(b, block_k, 0), block_n, 1)
    asp = _pad_to(_pad_to(a_scale, block_m, 0), block_k // 128, 1)
    bsp = _pad_to(_pad_to(b_scale, block_k // 128, 0), block_n // 128, 1)
    out = _sg.scaled_gemm(
        ap,
        bp,
        asp,
        bsp,
        block_m=block_m,
        block_n=block_n,
        block_k=block_k,
        grid_order=grid_order,
        scale_application=scale_application,
        interpret=interpret,
    )
    return out[:m, :n]


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "block_q", "block_k", "use_pallas", "interpret"),
)
def attention(
    q, k, v, *, causal=True, window=None, block_q=256, block_k=256,
    use_pallas=True, interpret=True,
):
    if not use_pallas:
        return _ref.attention(q, k, v, causal=causal, window=window)
    return _fa.flash_attention(
        q, k, v, causal=causal, window=window,
        block_q=block_q, block_k=block_k, interpret=interpret,
    )


@functools.partial(
    jax.jit, static_argnames=("block_k", "use_pallas", "interpret")
)
def decode_attention(q, k, v, kv_len, *, block_k=512, use_pallas=True, interpret=True):
    if not use_pallas:
        return _ref.decode_attention(q, k, v, kv_len)
    return _fa.decode_attention(q, k, v, kv_len, block_k=block_k, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("chunk", "use_pallas", "interpret"))
def ssd(x, dt, a, b, c, *, d_skip=None, chunk=128, use_pallas=True, interpret=True):
    """x: (B, S, H, P), dt: (B, S, H), a: (H,), b/c: (B, S, N)."""
    if not use_pallas:
        return _ref.ssd(x, dt, a, b, c, d_skip=d_skip)
    # fuse per-head scalars outside the kernel, move to (B, H, S, ...) layout
    dtx = jnp.einsum("bshp,bsh->bhsp", x.astype(jnp.float32), dt.astype(jnp.float32))
    la = jnp.transpose(dt.astype(jnp.float32) * a[None, None, :], (0, 2, 1))
    y = _ssd.ssd(dtx, la, b, c, chunk=chunk, interpret=interpret)
    y = jnp.transpose(y, (0, 2, 1, 3)).astype(x.dtype)  # back to (B, S, H, P)
    if d_skip is not None:
        y = (y.astype(jnp.float32) + x.astype(jnp.float32) * d_skip[None, None, :, None]).astype(x.dtype)
    return y
