"""Flash attention Pallas kernels (prefill + single-token decode).

TPU-native tiling: the (q, k) score tile lives in VMEM, the running softmax
statistics in VMEM scratch, and the grid pipelines HBM->VMEM block fetches.
Supports causal masking, GQA (grouped KV heads) and local (sliding-window)
attention — the latter is what makes ``recurrentgemma``'s 2048-window layers
linear in sequence length.

Block sizes are exposed as parameters so the Kernel Scientist can tune them
(see repro.core.autotune).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax < 0.5 calls it TPUCompilerParams; newer releases renamed it.
_CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams

NEG_INF = -1e30


def _flash_body(
    q_ref,
    k_ref,
    v_ref,
    o_ref,
    acc_ref,
    m_ref,
    l_ref,
    *,
    scale: float,
    block_q: int,
    block_k: int,
    k_steps: int,
    causal: bool,
    window,
):
    iq, ik = pl.program_id(2), pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q_start = iq * block_q
    k_start = ik * block_k

    # block-level visibility: skip blocks strictly above the causal diagonal
    # or strictly outside the local window.
    visible = True
    if causal:
        visible = jnp.logical_and(visible, k_start <= q_start + block_q - 1)
    if window is not None:
        visible = jnp.logical_and(visible, k_start + block_k - 1 > q_start - window)

    @pl.when(visible)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)  # (bq, d)
        k = k_ref[0, 0].astype(jnp.float32)  # (bk, d)
        v = v_ref[0, 0].astype(jnp.float32)  # (bk, d)
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale

        q_pos = q_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
        k_pos = k_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
        mask = jnp.ones((block_q, block_k), jnp.bool_)
        if causal:
            mask &= k_pos <= q_pos
        if window is not None:
            mask &= k_pos > q_pos - window
        s = jnp.where(mask, s, NEG_INF)

        m_prev, l_prev = m_ref[...], l_ref[...]
        m_cur = jnp.max(s, axis=1, keepdims=True)  # (bq, 1)
        m_new = jnp.maximum(m_prev, m_cur)
        # rows with no visible key yet keep m == NEG_INF; exp must stay 0 there
        p = jnp.where(s <= NEG_INF / 2, 0.0, jnp.exp(s - m_new))
        alpha = jnp.where(m_prev <= NEG_INF / 2, 0.0, jnp.exp(m_prev - m_new))
        l_ref[...] = l_prev * alpha + jnp.sum(p, axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jnp.dot(
            p, v, preferred_element_type=jnp.float32
        )
        m_ref[...] = m_new

    @pl.when(ik == k_steps - 1)
    def _store():
        l = l_ref[...]
        denom = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, 0] = (acc_ref[...] / denom).astype(o_ref.dtype)


def flash_attention(
    q,
    k,
    v,
    *,
    causal: bool = True,
    window: int | None = None,
    scale: float | None = None,
    block_q: int = 256,
    block_k: int = 256,
    interpret: bool = True,
):
    """q: (B, Hq, S, D); k, v: (B, Hkv, S, D); Hq % Hkv == 0 (GQA)."""
    b, hq, s, d = q.shape
    hkv = k.shape[1]
    g = hq // hkv
    block_q = min(block_q, s)
    block_k = min(block_k, s)
    assert s % block_q == 0 and s % block_k == 0, (s, block_q, block_k)
    scale = scale if scale is not None else 1.0 / (d**0.5)
    grid = (b, hq, s // block_q, s // block_k)

    body = functools.partial(
        _flash_body,
        scale=scale,
        block_q=block_q,
        block_k=block_k,
        k_steps=s // block_k,
        causal=causal,
        window=window,
    )
    return pl.pallas_call(
        body,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, d), lambda bb, h, iq, ik: (bb, h, iq, 0)),
            pl.BlockSpec((1, 1, block_k, d), lambda bb, h, iq, ik, g=g: (bb, h // g, ik, 0)),
            pl.BlockSpec((1, 1, block_k, d), lambda bb, h, iq, ik, g=g: (bb, h // g, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, d), lambda bb, h, iq, ik: (bb, h, iq, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, d), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel", "arbitrary")
        ),
        interpret=interpret,
    )(q, k, v)


# ---------------------------------------------------------------------------
# Decode attention: one new token against a long KV cache
# ---------------------------------------------------------------------------
def _decode_body(
    len_ref,
    q_ref,
    k_ref,
    v_ref,
    o_ref,
    acc_ref,
    m_ref,
    l_ref,
    *,
    scale: float,
    block_k: int,
    k_steps: int,
):
    bb, ik = pl.program_id(0), pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    kv_len = len_ref[bb]
    k_start = ik * block_k

    @pl.when(k_start < kv_len)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)  # (g, d) — the GQA query group
        k = k_ref[0, 0].astype(jnp.float32)  # (bk, d)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale  # (g, bk)
        k_pos = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(k_pos < kv_len, s, NEG_INF)

        m_prev, l_prev = m_ref[...], l_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.where(s <= NEG_INF / 2, 0.0, jnp.exp(s - m_new))
        alpha = jnp.where(m_prev <= NEG_INF / 2, 0.0, jnp.exp(m_prev - m_new))
        l_ref[...] = l_prev * alpha + jnp.sum(p, axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jnp.dot(
            p, v, preferred_element_type=jnp.float32
        )
        m_ref[...] = m_new

    @pl.when(ik == k_steps - 1)
    def _store():
        l = l_ref[...]
        denom = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, 0] = (acc_ref[...] / denom).astype(o_ref.dtype)


def decode_attention(
    q,
    k,
    v,
    kv_len,
    *,
    scale: float | None = None,
    block_k: int = 512,
    interpret: bool = True,
):
    """q: (B, Hq, D); k, v: (B, Hkv, S, D); kv_len: (B,) int32 valid lengths.

    The GQA group (Hq // Hkv queries sharing one KV head) forms the row block,
    so the MXU sees a (g, d) x (d, bk) matmul per step instead of a degenerate
    single-row product.
    """
    b, hq, d = q.shape
    hkv, s = k.shape[1], k.shape[2]
    g = hq // hkv
    block_k = min(block_k, s)
    assert s % block_k == 0
    scale = scale if scale is not None else 1.0 / (d**0.5)
    qg = q.reshape(b, hkv, g, d)
    grid = (b, hkv, s // block_k)

    body = functools.partial(
        _decode_body, scale=scale, block_k=block_k, k_steps=s // block_k
    )
    out = pl.pallas_call(
        body,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                # index maps get the scalar-prefetch ref as a trailing arg
                pl.BlockSpec((1, 1, g, d), lambda bb, h, ik, _len: (bb, h, 0, 0)),
                pl.BlockSpec((1, 1, block_k, d), lambda bb, h, ik, _len: (bb, h, ik, 0)),
                pl.BlockSpec((1, 1, block_k, d), lambda bb, h, ik, _len: (bb, h, ik, 0)),
            ],
            out_specs=pl.BlockSpec((1, 1, g, d), lambda bb, h, ik, _len: (bb, h, 0, 0)),
            scratch_shapes=[
                pltpu.VMEM((g, d), jnp.float32),
                pltpu.VMEM((g, 1), jnp.float32),
                pltpu.VMEM((g, 1), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((b, hkv, g, d), q.dtype),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")
        ),
        interpret=interpret,
    )(kv_len.astype(jnp.int32), qg, k, v)
    return out.reshape(b, hq, d)
