"""Mamba-2 SSD (state-space duality) chunked-scan Pallas kernel.

The SSD algorithm splits the sequence into chunks: within a chunk the
recurrence is computed as a (masked, decay-weighted) quadratic attention-like
matmul (MXU-friendly); across chunks a small (N x P) state is carried.  On
TPU the state lives in VMEM scratch across sequential grid steps — the
analogue of the paper's LDS-resident accumulators on MI300.

Inputs are pre-fused by ops.py: ``dtx = x * dt`` and ``la = dt * A`` so the
kernel carries no per-head scalars.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax < 0.5 calls it TPUCompilerParams; newer releases renamed it.
_CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams


def _ssd_body(dtx_ref, la_ref, b_ref, c_ref, y_ref, state_ref, *, chunk: int):
    ic = pl.program_id(2)

    @pl.when(ic == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    dtx = dtx_ref[0, 0].astype(jnp.float32)  # (L, P)
    la = la_ref[0, 0].astype(jnp.float32).reshape(chunk, 1)  # (L, 1) log-decay
    bmat = b_ref[0].astype(jnp.float32)  # (L, N)
    cmat = c_ref[0].astype(jnp.float32)  # (L, N)

    cum = jnp.cumsum(la, axis=0)  # (L, 1) inclusive
    # intra-chunk: y_i += sum_{j<=i} exp(cum_i - cum_j) (c_i . b_j) dtx_j
    seg = cum - cum.reshape(1, chunk)  # (L, L): cum_i - cum_j
    ii = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    lmat = jnp.exp(jnp.where(jj <= ii, seg, -jnp.inf))  # mask pre-exp
    scores = jnp.dot(cmat, bmat.T, preferred_element_type=jnp.float32) * lmat
    y = jnp.dot(scores, dtx, preferred_element_type=jnp.float32)

    # inter-chunk: contribution of the state at chunk entry
    state = state_ref[...]  # (N, P)
    y = y + jnp.exp(cum) * jnp.dot(cmat, state, preferred_element_type=jnp.float32)

    # state update: S <- exp(cum_L) S + sum_j exp(cum_L - cum_j) b_j (x dt)_j
    decay_all = jnp.exp(cum[-1])  # scalar-ish (1,)
    w = jnp.exp(cum[-1] - cum)  # (L, 1)
    state_ref[...] = decay_all * state + jnp.dot(
        (bmat * w).T, dtx, preferred_element_type=jnp.float32
    )

    y_ref[0, 0] = y.astype(y_ref.dtype)


def ssd(
    dtx,
    la,
    b,
    c,
    *,
    chunk: int = 128,
    interpret: bool = True,
):
    """dtx: (B, H, S, P); la: (B, H, S); b, c: (B, S, N).  Returns (B, H, S, P)."""
    bsz, h, s, p = dtx.shape
    n = b.shape[-1]
    chunk = min(chunk, s)
    assert s % chunk == 0, (s, chunk)
    grid = (bsz, h, s // chunk)

    body = functools.partial(_ssd_body, chunk=chunk)
    return pl.pallas_call(
        body,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, chunk, p), lambda bb, hh, ic: (bb, hh, ic, 0)),
            pl.BlockSpec((1, 1, chunk), lambda bb, hh, ic: (bb, hh, ic)),
            pl.BlockSpec((1, chunk, n), lambda bb, hh, ic: (bb, ic, 0)),
            pl.BlockSpec((1, chunk, n), lambda bb, hh, ic: (bb, ic, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, chunk, p), lambda bb, hh, ic: (bb, hh, ic, 0)),
        out_shape=jax.ShapeDtypeStruct((bsz, h, s, p), dtx.dtype),
        scratch_shapes=[pltpu.VMEM((n, p), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")
        ),
        interpret=interpret,
    )(dtx, la, b, c)
