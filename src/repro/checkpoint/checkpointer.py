"""Topology-agnostic, atomic, async checkpointing.

Checkpoints store *logical* arrays (host numpy) keyed by tree path, plus a
manifest — nothing about the mesh is persisted, so a checkpoint written on
a (16,16) mesh restores onto (2,16,16), a debug (2,2), or a single device:
``restore`` re-shards every leaf to the shardings the caller provides
(elastic re-scale).  Writes go to a temp dir + atomic rename with a COMMIT
marker, so a preempted writer can never corrupt the latest checkpoint;
``latest_step`` only considers committed checkpoints.  ``save_async``
snapshots to host and writes on a background thread (training continues).
"""
from __future__ import annotations

import json
import pathlib
import shutil
import threading
from typing import Any, Optional

import jax
import numpy as np

_MANIFEST = "manifest.json"
_COMMIT = "COMMIT"


def _flatten(tree) -> dict:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                       for k in path)
        flat[key] = leaf
    return flat


class Checkpointer:
    def __init__(self, directory, keep: int = 3):
        self.dir = pathlib.Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self._thread: Optional[threading.Thread] = None

    # ------------------------------------------------------------------ io
    def _step_dir(self, step: int) -> pathlib.Path:
        return self.dir / f"step_{step:010d}"

    def save(self, step: int, tree: Any, extra: Optional[dict] = None):
        """Synchronous atomic save."""
        host = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)
        self._write(step, host, extra or {})

    def save_async(self, step: int, tree: Any,
                   extra: Optional[dict] = None):
        """Snapshot to host now; write on a background thread."""
        self.wait()
        host = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)
        self._thread = threading.Thread(
            target=self._write, args=(step, host, extra or {}), daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _write(self, step: int, host_tree, extra: dict):
        final = self._step_dir(step)
        tmp = final.with_name(final.name + ".tmp")
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)
        flat = _flatten(host_tree)
        np.savez(tmp / "arrays.npz", **flat)
        treedef = jax.tree_util.tree_structure(host_tree)
        (tmp / _MANIFEST).write_text(json.dumps({
            "step": step,
            "keys": sorted(flat),
            "treedef": str(treedef),
            "extra": extra,
        }))
        (tmp / _COMMIT).write_text("ok")
        if final.exists():
            shutil.rmtree(final)
        tmp.rename(final)            # atomic on POSIX
        self._gc()

    def _gc(self):
        steps = self.all_steps()
        for s in steps[:-self.keep] if self.keep else []:
            shutil.rmtree(self._step_dir(s), ignore_errors=True)

    # --------------------------------------------------------------- query
    def all_steps(self) -> list:
        out = []
        for p in sorted(self.dir.glob("step_*")):
            if p.suffix == ".tmp" or not (p / _COMMIT).exists():
                continue
            out.append(int(p.name.split("_")[1]))
        return out

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    # ------------------------------------------------------------- restore
    def restore(self, step: int, target_tree: Any, shardings: Any = None):
        """Restore into the structure of ``target_tree`` (abstract or
        concrete), placing each leaf with ``shardings`` (tree of Sharding or
        None => default device placement).  The mesh may differ arbitrarily
        from the one that wrote the checkpoint."""
        d = self._step_dir(step)
        assert (d / _COMMIT).exists(), f"no committed checkpoint at {d}"
        arrays = np.load(d / "arrays.npz")
        flat_target = _flatten(target_tree)
        missing = set(flat_target) - set(arrays.files)
        assert not missing, f"checkpoint missing keys: {sorted(missing)[:5]}"

        flat_shard = (_flatten(shardings) if shardings is not None
                      else {k: None for k in flat_target})
        leaves_by_key = {}
        for key, tgt in flat_target.items():
            arr = arrays[key]
            assert tuple(arr.shape) == tuple(tgt.shape), (
                key, arr.shape, tgt.shape)
            tdt = np.dtype(tgt.dtype)
            if arr.dtype != tdt:
                # ml_dtypes (bfloat16, fp8) survive npz as void records of
                # the right width — reinterpret, never cast
                assert arr.dtype.itemsize == tdt.itemsize, (key, arr.dtype,
                                                            tdt)
                arr = arr.view(tdt)
            sh = flat_shard.get(key)
            leaves_by_key[key] = (jax.device_put(arr, sh) if sh is not None
                                  else jax.device_put(arr))

        # rebuild in target tree order
        paths, treedef = jax.tree_util.tree_flatten_with_path(target_tree)
        ordered = []
        for path, _ in paths:
            key = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                           for k in path)
            ordered.append(leaves_by_key[key])
        return jax.tree_util.tree_unflatten(treedef, ordered)

    def extra(self, step: int) -> dict:
        d = self._step_dir(step)
        return json.loads((d / _MANIFEST).read_text())["extra"]
