from .step import (  # noqa: F401
    make_decode_step, make_loss, make_prefill_step, make_train_step,
)
