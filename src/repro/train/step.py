"""The jitted train / prefill / decode step factories.

``make_train_step`` returns the exact function the dry-run lowers for
``train_*`` shapes: forward + backward + AdamW update, with params and
optimizer state donated (in-place buffers — this is what makes the
memory_analysis numbers honest)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.models import api
from repro.optim import adamw, schedule


def make_loss(cfg):
    def loss(params, batch):
        return api.loss_fn(params, cfg, batch)
    return loss


def _split_microbatches(batch: dict, n: int) -> dict:
    """Reshape every batch leaf to (n, B/n, ...); mrope positions carry the
    batch on axis 1."""
    def split(key, x):
        ax = 1 if key == "positions" else 0
        assert x.shape[ax] % n == 0, (key, x.shape, n)
        new = x.shape[:ax] + (n, x.shape[ax] // n) + x.shape[ax + 1:]
        x = x.reshape(new)
        return jnp.moveaxis(x, ax, 0) if ax else x
    return {k: split(k, v) for k, v in batch.items()}


def make_train_step(cfg, *, peak_lr: float = 3e-4, warmup_steps: int = 100,
                    total_steps: int = 10_000,
                    opt_cfg: adamw.AdamWConfig = adamw.AdamWConfig(),
                    microbatches: int = 1):
    """Forward+backward+AdamW.  microbatches > 1 scans gradient
    accumulation over batch slices (activation/dispatch memory scales down
    by the factor; the f32 gradient accumulator inherits the FSDP parameter
    sharding)."""
    loss_fn = make_loss(cfg)

    def grads_of(params, batch):
        return jax.value_and_grad(loss_fn, has_aux=True)(params, batch)

    def train_step(params, opt_state, batch, step):
        if microbatches == 1:
            (loss, metrics), grads = grads_of(params, batch)
        else:
            mb = _split_microbatches(batch, microbatches)

            def body(acc, mbatch):
                (l, m), g = grads_of(params, mbatch)
                acc = jax.tree.map(
                    lambda a, gi: a + gi.astype(jnp.float32), acc, g)
                return acc, (l, m)

            acc0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                                params)
            gsum, (losses, ms) = jax.lax.scan(body, acc0, mb)
            grads = jax.tree.map(
                lambda g, p: (g / microbatches).astype(p.dtype), gsum, params)
            loss = jnp.mean(losses)
            metrics = jax.tree.map(jnp.mean, ms)
        lr = schedule.cosine_with_warmup(
            step, peak_lr=peak_lr, warmup_steps=warmup_steps,
            total_steps=total_steps)
        params, opt_state, opt_metrics = adamw.update(
            grads, opt_state, params, lr, opt_cfg)
        metrics = dict(metrics, loss=loss, lr=lr, **opt_metrics)
        return params, opt_state, metrics

    return train_step


def make_prefill_step(cfg, max_seq: int, microbatches: int = 1):
    """Prefill; microbatches > 1 scans batch slices (chunked admission) so
    MoE-dispatch/attention transients shrink while the returned cache is the
    full batch."""
    def prefill_step(params, batch):
        if microbatches == 1:
            return api.prefill(params, cfg, batch, max_seq)
        mb = _split_microbatches(batch, microbatches)

        def body(_, mbatch):
            return None, api.prefill(params, cfg, mbatch, max_seq)

        _, (logits, cache) = jax.lax.scan(body, None, mb)

        def merge(key, x):      # (n, ..., B/n, ...) -> (..., B, ...)
            ax = 0 if key in ("len", "_logits") else 1
            x = jnp.moveaxis(x, 0, ax)
            return x.reshape(x.shape[:ax] + (-1,) + x.shape[ax + 2:])

        logits = merge("_logits", logits)
        cache = {k: merge(k, v) for k, v in cache.items()} if cache else None
        return logits, cache
    return prefill_step


def make_decode_step(cfg):
    def serve_step(params, cache, tokens):
        return api.decode_step(params, cfg, cache, tokens)
    return serve_step
