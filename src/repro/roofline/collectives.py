"""Collective-byte extraction from optimized HLO text.

``cost_analysis()`` has no collective term, so we parse the compiled module:
sum the operand sizes of every all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute instruction.  SPMD-partitioned HLO shapes
are per-device, and the while-loop (scan) body appears once — callers apply
the same L-correction they use for FLOPs.
"""
from __future__ import annotations

import re

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

# e.g.:  %ag = bf16[4,1024,128]{2,1,0} all-gather(%x), ...
_INSTR_RE = re.compile(
    r"=\s*((?:\(|)[a-z0-9]+\[[^=]*?)\s*"
    r"(" + "|".join(_COLLECTIVES) + r")(?:-start|-done)?\(")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([\d,]*)\]")


def _shape_bytes(shape_text: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_text):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def collective_bytes_from_hlo(hlo_text: str) -> float:
    """Sum of output-shape bytes over all collective instructions (per
    device).  `-done` ops are skipped so async pairs count once."""
    total = 0
    for line in hlo_text.splitlines():
        m = _INSTR_RE.search(line)
        if not m:
            continue
        if f"{m.group(2)}-done(" in line:
            continue
        total += _shape_bytes(m.group(1))
    return float(total)


def collective_op_counts(hlo_text: str) -> dict:
    out: dict[str, int] = {}
    for line in hlo_text.splitlines():
        m = _INSTR_RE.search(line)
        if not m or f"{m.group(2)}-done(" in line:
            continue
        out[m.group(2)] = out.get(m.group(2), 0) + 1
    return out
