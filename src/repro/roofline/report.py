"""Roofline assembly: dry-run JSON records -> per-cell three-term table.

Terms (TPU v5e, per spec):
  compute    = HLO_FLOPs_per_chip / 197 TFLOP/s (bf16)
  memory     = HLO_bytes_per_chip / 819 GB/s HBM
  collective = collective_bytes_per_chip / 50 GB/s per ICI link
               (single-link: conservative; a 2D-torus ring phase can use 2)

cost_analysis on the SPMD-partitioned module reports per-chip numbers, and
counts every while-loop body once.  The dry-run therefore recorded three
lowerings per single-pod cell (see launch/dryrun.py): `exact1` (inner scans
unrolled), `exact2` (each layer stack executed twice).  The corrected
per-chip cost is

    corrected = exact1 + (body_repeats - 1) * (exact2 - exact1) / n_stacks

MODEL_FLOPS uses 6*N*T (train, N=active params, T=tokens/step), 2*N*T
(prefill), 2*N*B (decode: one token per sequence).
"""
from __future__ import annotations

import glob
import json
import pathlib

from repro import configs
from repro.models import SHAPES

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_LINK_BW = 50e9

_KEYS = ("flops", "bytes_accessed", "collective_bytes")


def flat_cost_analysis(compiled) -> dict:
    """``Compiled.cost_analysis()`` returns ``[per-program dict]`` on
    jax < 0.5 and a single flat dict on newer releases; normalise to the
    flat dict either way."""
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return dict(cost)


def corrected_costs(rec: dict) -> dict:
    """Scan-corrected per-chip costs for a single-pod record.

    The microbatch (grad-accumulation / chunked-admission) scan is itself a
    while loop counted once, so the layer-corrected total scales by the
    cell's microbatch factor."""
    from repro.launch.dryrun import PREFILL_MICROBATCHES, TRAIN_MICROBATCHES
    if "exact1" not in rec:
        return dict(rec["prod"])
    e1, e2 = rec["exact1"], rec["exact2"]
    r = rec["body_repeats"]
    ns = rec["n_stacks"]
    mb = 1
    if rec["shape"].startswith("train"):
        mb = TRAIN_MICROBATCHES.get(rec["arch"], 1)
    elif rec["shape"].startswith("prefill"):
        mb = PREFILL_MICROBATCHES.get(rec["arch"], 1)
    out = {}
    for k in _KEYS:
        body = max(e2[k] - e1[k], 0.0)
        out[k] = (e1[k] + (r - 1) * body / ns) * mb
    return out


def model_flops(arch_id: str, shape_name: str) -> float:
    cfg = configs.get_config(arch_id)
    shape = SHAPES[shape_name]
    n = cfg.active_param_count()
    if shape.kind == "train":
        return 6.0 * n * shape.seq_len * shape.global_batch
    if shape.kind == "prefill":
        return 2.0 * n * shape.seq_len * shape.global_batch
    return 2.0 * n * shape.global_batch          # decode: 1 token/sequence


def cell_report(rec: dict) -> dict:
    cost = corrected_costs(rec)
    n_dev = rec["n_devices"]
    compute_s = cost["flops"] / PEAK_FLOPS
    memory_s = cost["bytes_accessed"] / HBM_BW
    coll_s = cost["collective_bytes"] / ICI_LINK_BW
    terms = {"compute": compute_s, "memory": memory_s, "collective": coll_s}
    dominant = max(terms, key=terms.get)
    mf = model_flops(rec["arch"], rec["shape"])
    useful = mf / (cost["flops"] * n_dev) if cost["flops"] else 0.0
    bound_s = max(terms.values())
    mem = rec["prod"].get("memory", {})
    hbm_gib = (mem.get("argument_size_in_bytes", 0)
               + mem.get("temp_size_in_bytes", 0)) / 2**30
    suggestions = {
        "compute": "cut non-model FLOPs: lighter remat policy, fused "
                   "attention kernel, loss-chunk fusion",
        "memory": "raise arithmetic intensity: larger fused blocks, "
                  "bf16/int8 residuals, fewer re-streamed operands",
        "collective": "re-shard to shrink gathered operands / overlap "
                      "collectives with compute (collective matmul)",
    }
    return {
        "arch": rec["arch"], "shape": rec["shape"],
        "n_devices": n_dev,
        "compute_s": compute_s, "memory_s": memory_s,
        "collective_s": coll_s,
        "dominant": dominant,
        "step_lower_bound_s": bound_s,
        "roofline_fraction": compute_s / bound_s if bound_s else 0.0,
        "model_flops": mf,
        "useful_flops_ratio": useful,
        "hbm_gib_per_device": hbm_gib,
        "fits_hbm": hbm_gib <= 16.0,
        "what_would_help": suggestions[dominant],
    }


def load_records(result_dir) -> list:
    recs = []
    for f in sorted(glob.glob(str(pathlib.Path(result_dir) / "*.json"))):
        recs.append(json.loads(pathlib.Path(f).read_text()))
    return recs


def assemble(result_dir, mesh: str = "single") -> list:
    rows = []
    for rec in load_records(result_dir):
        if rec["mesh"] != mesh or rec["status"] != "ok":
            continue
        rows.append(cell_report(rec))
    return rows


def markdown_table(rows: list) -> str:
    hdr = ("| arch | shape | compute (s) | memory (s) | collective (s) | "
           "dominant | 6N·T/HLO | HBM GiB/dev | fits |\n"
           "|---|---|---|---|---|---|---|---|---|")
    lines = [hdr]
    for r in sorted(rows, key=lambda r: (r["arch"], r["shape"])):
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.3f} | "
            f"{r['memory_s']:.3f} | {r['collective_s']:.3f} | "
            f"**{r['dominant']}** | {r['useful_flops_ratio']:.2f} | "
            f"{r['hbm_gib_per_device']:.1f} | "
            f"{'yes' if r['fits_hbm'] else 'NO'} |")
    return "\n".join(lines)
