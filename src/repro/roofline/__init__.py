from .collectives import collective_bytes_from_hlo, collective_op_counts  # noqa: F401
